//! `swap-train` — the L3 leader binary. Dispatches CLI subcommands onto
//! the experiment drivers. See `swap-train help` / cli::HELP.

use swap::cli::{default_preset_for, Args, HELP};
use swap::runtime::Backend;
use swap::util::{Error, Result};
use swap::coordinator::{
    join_phase1, join_run, run_baseline, run_local_sgd, run_swa, run_swap,
    run_swap_resumable_with, LocalSgdConfig, Phase1Outcome, RunDir, SocketTransport,
};
use swap::experiments::{figures, tables, Lab};
use swap::landscape::GridSpec;
use swap::serving::{percentile, ServeModel, Server};

/// Persist the averaged model + recomputed BN stats as a servable
/// checkpoint bundle (`serve-model --model` loads it back).
fn save_servable(
    out: &str,
    manifest: &swap::runtime::Manifest,
    params: &swap::model::ParamSet,
    bn: &swap::model::BnState,
) -> Result<()> {
    std::fs::create_dir_all(out)?;
    let path = std::path::Path::new(out).join("model.ckpt");
    swap::model::save_model(&path, manifest, params, bn)?;
    println!("saved servable model: {}", path.display());
    Ok(())
}

fn main() -> Result<()> {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let args = Args::parse(&argv)?;
    let cmd = args.command.as_str();
    if cmd == "help" || cmd == "--help" {
        println!("{HELP}");
        return Ok(());
    }
    let cfg = args.config(default_preset_for(cmd))?;

    match cmd {
        "info" => {
            println!("{cfg:#?}");
            let lab = Lab::new(cfg)?;
            println!("manifest: {:#?}", lab.engine.manifest());
        }
        "swap" => {
            let lab = Lab::new(cfg)?;
            let r = run_swap(&lab.env(), &lab.swap_arm(lab.cfg.seed))?;
            println!(
                "SWAP: phase1 {:.1} epochs (train acc {:.3}) | before avg {:.4} | after avg {:.4}",
                r.phase1.epochs,
                r.phase1.train_acc,
                r.before_avg_acc1(),
                r.final_stats.accuracy1()
            );
            println!(
                "modeled time: phase1 {:.2}s, total {:.2}s (compute {:.2}s, comm {:.2}s); wall {:.1}s",
                r.phase1_seconds, r.clock.seconds, r.clock.compute, r.clock.comm, r.wall_seconds
            );
            if let Some(out) = args.get("out") {
                save_servable(out, lab.engine.manifest(), &r.final_params, &r.final_bn)?;
            }
        }
        "sb" | "lb" => {
            let lab = Lab::new(cfg)?;
            let arm = if cmd == "sb" {
                lab.sb_arm(lab.cfg.seed)
            } else {
                lab.lb_arm(lab.cfg.seed)
            };
            let r = run_baseline(&lab.env(), &arm)?;
            println!(
                "{}: test acc {:.4} (top5 {:.4}) | modeled {:.2}s | wall {:.1}s | {:.1} epochs (train acc {:.3})",
                cmd.to_uppercase(),
                r.outcome.test_acc1,
                r.outcome.test_acc5,
                r.outcome.cluster_seconds,
                r.outcome.wall_seconds,
                r.progress.epochs,
                r.progress.train_acc
            );
        }
        "swa" => {
            let lab = Lab::new(cfg)?;
            let env = lab.env();
            let sb = run_baseline(&env, &lab.sb_arm(lab.cfg.seed))?;
            let mut params = sb.params;
            let mut clock = sb.clock;
            let r = run_swa(
                &env,
                &mut params,
                &lab.swa_arm(1, lab.cfg.swa_cycles, lab.cfg.seed),
                &mut clock,
            )?;
            println!(
                "SWA: before avg {:.4} | after avg {:.4} | modeled {:.2}s",
                r.last_stats.accuracy1(),
                r.final_stats.accuracy1(),
                clock.seconds
            );
        }
        "local-sgd" => {
            let lab = Lab::new(cfg)?;
            let spe = lab.spe(lab.cfg.lb_devices);
            let r = run_local_sgd(
                &lab.env(),
                &LocalSgdConfig {
                    devices: lab.cfg.lb_devices,
                    sync_epochs: (lab.cfg.phase1_max_epochs / 2).max(1),
                    sync_sched: lab.cfg.phase1_schedule(spe),
                    local_epochs: lab.cfg.phase2_epochs,
                    local_sched: lab.cfg.phase2_schedule(lab.spe(1)),
                    h_steps: 8,
                    seed: lab.cfg.seed,
                    averaging: lab.averaging.clone(),
                },
            )?;
            println!(
                "post-local SGD: test acc {:.4} | modeled {:.2}s | {} sync events",
                r.outcome.test_acc1, r.outcome.cluster_seconds, r.sync_events
            );
        }
        "table1" | "table2" | "table3" | "table4" | "dawnbench" => {
            let lab = Lab::new(cfg)?;
            let t = match cmd {
                "table1" => tables::table1(&lab)?,
                "table2" => tables::table2(&lab)?,
                "table3" => tables::table3(&lab)?,
                "table4" => tables::table4(&lab)?,
                _ => tables::dawnbench(&lab, 0.95)?,
            };
            t.print();
            tables::save_table(&t, cmd)?;
            println!("saved results/{cmd}.txt and .csv");
        }
        "fig1" => {
            let lab = Lab::new(cfg)?;
            let (_lr, acc) = figures::fig1(&lab)?;
            println!(
                "fig1 written: results/fig1_lr.csv, results/fig1_accuracy.csv ({} rows)",
                acc.len()
            );
        }
        "fig2" | "fig3" | "landscape" => {
            let lab = Lab::new(cfg)?;
            let figs = figures::fig2_fig3(&lab, &GridSpec::default())?;
            println!(
                "fig2/fig3 written under results/. best test err on fig3 plane: {:.4} at ({:.2},{:.2})",
                figs.fig3.best_test.test_err, figs.fig3.best_test.alpha, figs.fig3.best_test.beta
            );
        }
        "fig4" => {
            let lab = Lab::new(cfg)?;
            let s = figures::fig4(&lab)?;
            println!("fig4 written: results/fig4_cosine.csv ({} rows)", s.len());
        }
        "schedules" | "fig5" | "fig6" => {
            let lab = Lab::new(cfg)?;
            let a = figures::fig5(&lab)?;
            let b = figures::fig6(&lab)?;
            println!(
                "fig5 ({} rows) and fig6 ({} rows) written under results/",
                a.len(),
                b.len()
            );
        }
        "swap-resume" => {
            // restartable SWAP: phase-1 + finished workers are persisted
            // under --out (default runs/<preset>) and skipped on re-entry
            let out = args
                .get("out")
                .map(|s| s.to_string())
                .unwrap_or_else(|| format!("runs/{}", cfg.preset));
            let lab = Lab::new(cfg)?;
            let dir = RunDir::new(&out)?;
            let r = swap::coordinator::run_swap_resumable(&lab.env(), &lab.swap_arm(lab.cfg.seed), &dir)?;
            println!(
                "SWAP (resumable, state in {out}): after avg {:.4} | modeled {:.2}s | wall {:.1}s",
                r.final_stats.accuracy1(),
                r.clock.seconds,
                r.wall_seconds
            );
            save_servable(&out, lab.engine.manifest(), &r.final_params, &r.final_bn)?;
        }
        "serve" => {
            // coordinator for multi-process SWAP: phase 1 runs here, phase
            // 2 is served to `join` processes over the socket; checkpoints
            // live under --out, so re-serving retries only dropped workers
            let addr = args
                .get("addr")
                .map(|s| s.to_string())
                .unwrap_or_else(|| cfg.addr.clone());
            if addr.is_empty() {
                return Err(Error::config(
                    "serve needs an address: --addr host:port (TCP) or --addr /path/to.sock",
                ));
            }
            let out = args
                .get("out")
                .map(|s| s.to_string())
                .unwrap_or_else(|| format!("runs/{}", cfg.preset));
            let policy = cfg.failure_policy();
            let lab = Lab::new(cfg)?;
            let dir = RunDir::new(&out)?;
            let transport = SocketTransport::new(addr.clone());
            let r = run_swap_resumable_with(
                &lab.env(),
                &lab.swap_arm(lab.cfg.seed),
                &dir,
                &transport,
                &policy,
            )?;
            println!(
                "SWAP (served on {addr}, state in {out}): after avg {:.4} | {}/{} workers averaged, {} dropped | {:.1} MiB moved | modeled {:.2}s (+{:.2}s lost)",
                r.final_stats.accuracy1(),
                r.worker_params.len(),
                lab.cfg.workers,
                r.dropped.len(),
                r.net.framed_bytes as f64 / (1024.0 * 1024.0),
                r.clock.seconds,
                r.clock.lost
            );
            for (w, reason) in &r.dropped {
                println!("  dropped worker {w}: {reason}");
            }
        }
        "join" => {
            // one phase-2 worker process: train the assigned replica
            // against a `serve` coordinator and upload it
            let addr = args
                .get("addr")
                .map(|s| s.to_string())
                .unwrap_or_else(|| cfg.addr.clone());
            if addr.is_empty() {
                return Err(Error::config(
                    "join needs an address: --addr host:port (TCP) or --addr /path/to.sock",
                ));
            }
            let want = match args.get("worker") {
                Some(s) => Some(s.parse::<usize>().map_err(|_| {
                    Error::config(format!("--worker wants a worker id, got '{s}'"))
                })?),
                None => None,
            };
            let policy = cfg.failure_policy();
            let lab = Lab::new(cfg)?;
            let env = lab.env();
            let swap_cfg = lab.swap_arm(lab.cfg.seed);
            if swap_cfg.phase1_dist {
                // the coordinator runs phase 1 as a distributed collective:
                // contribute gradient shards first, then fall through to the
                // phase-2 join (a late joiner finds phase 1 already done)
                match join_phase1(&env, &swap_cfg, &addr, &policy, want)? {
                    Phase1Outcome::Participated(p) => println!(
                        "phase 1 on {addr} as member {}: {} sync steps (from {}) | sent {} B, received {} B",
                        p.slot, p.steps, p.first_step, p.bytes_sent, p.bytes_received
                    ),
                    Phase1Outcome::AlreadyDone => {
                        println!("phase 1 on {addr} already complete; joining phase 2")
                    }
                }
            }
            let s = join_run(&env, &swap_cfg, &addr, &policy, want)?;
            println!(
                "joined {addr} as worker {}: {} steps | sent {} B, received {} B",
                s.worker, s.steps, s.bytes_sent, s.bytes_received
            );
        }
        "serve-model" => {
            // batched inference on a saved averaged-model checkpoint:
            // requests from concurrent clients coalesce through the
            // dynamic batcher onto serve_threads shard engines
            let model_path = args
                .get("model")
                .map(|s| s.to_string())
                .unwrap_or_else(|| format!("runs/{}/model.ckpt", cfg.preset));
            swap::util::simd::set_active(&cfg.simd)?;
            let tier = cfg.serve_tier()?;
            let model =
                std::sync::Arc::new(ServeModel::load(cfg.native_spec(), &model_path, tier)?);
            let (_, test) = cfg.data_source()?.load()?;
            let server = Server::start(model, cfg.serve_config())?;
            let pix = test.image_size * test.image_size * 3;
            let clients = (server.config().shards * server.config().max_batch).clamp(1, test.n);
            let correct = std::sync::atomic::AtomicUsize::new(0);
            let t0 = std::time::Instant::now();
            let mut lats: Vec<f64> = std::thread::scope(|s| {
                let handles: Vec<_> = (0..clients)
                    .map(|c| {
                        let (server, test, correct) = (&server, &test, &correct);
                        s.spawn(move || {
                            let mut lat = Vec::new();
                            let mut i = c;
                            while i < test.n {
                                let img = &test.images[i * pix..(i + 1) * pix];
                                let q0 = std::time::Instant::now();
                                // a small serve_queue_depth sheds under this
                                // client storm: back off and retry
                                let top1 = loop {
                                    match server.classify(img) {
                                        Ok(t) => break t,
                                        Err(e) if e.is_overloaded() => std::thread::sleep(
                                            std::time::Duration::from_micros(200),
                                        ),
                                        Err(e) => panic!("serve request failed: {e}"),
                                    }
                                };
                                lat.push(q0.elapsed().as_secs_f64() * 1e3);
                                if top1 as i32 == test.labels[i] {
                                    correct.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
                                }
                                i += clients;
                            }
                            lat
                        })
                    })
                    .collect();
                handles.into_iter().flat_map(|h| h.join().unwrap()).collect()
            });
            let wall = t0.elapsed().as_secs_f64();
            lats.sort_by(f64::total_cmp);
            let st = server.stats();
            println!(
                "serve-model [{}] {}: {} requests from {} clients over {} shards",
                tier.name(),
                model_path,
                st.requests,
                clients,
                server.config().shards
            );
            println!(
                "  acc {:.4} | mean batch {:.2} (max {}) | p50 {:.3} ms  p99 {:.3} ms | {:.0} req/s | {} shed",
                correct.load(std::sync::atomic::Ordering::Relaxed) as f64 / test.n.max(1) as f64,
                st.mean_batch(),
                st.max_batch_seen,
                percentile(&lats, 50.0),
                percentile(&lats, 99.0),
                test.n as f64 / wall.max(1e-9),
                st.sheds
            );
        }
        "ablate-workers" | "ablate-tau" | "ablate-phase2" | "ablate-freq" | "ablate-net" => {
            use swap::experiments::ablations as ab;
            let lab = Lab::new(cfg)?;
            let t = match cmd {
                "ablate-workers" => ab::ablate_workers(&lab, &[2, 4, 8])?,
                "ablate-tau" => ab::ablate_tau(&lab, &[0.3, 0.5, 0.7, 1.1])?,
                "ablate-phase2" => ab::ablate_phase2(&lab, &[2, 4, 8, 16])?,
                "ablate-freq" => ab::ablate_averaging_frequency(&lab, &[1, 8, 64])?,
                _ => ab::ablate_network(&lab)?,
            };
            t.print();
            tables::save_table(&t, cmd)?;
        }
        "e2e" => {
            let lab = Lab::new(cfg)?;
            let env = lab.env();
            let sb = run_baseline(&env, &lab.sb_arm(lab.cfg.seed))?;
            let r = run_swap(&env, &lab.swap_arm(lab.cfg.seed))?;
            println!(
                "e2e: SB acc {:.4} ({:.1}s modeled) | SWAP acc {:.4} ({:.1}s modeled, {:.2}x)",
                sb.outcome.test_acc1,
                sb.outcome.cluster_seconds,
                r.final_stats.accuracy1(),
                r.clock.seconds,
                r.clock.seconds / sb.outcome.cluster_seconds
            );
        }
        other => {
            eprintln!("unknown command '{other}'\n\n{HELP}");
            std::process::exit(2);
        }
    }
    Ok(())
}
