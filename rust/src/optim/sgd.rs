//! Host-side SGD + Nesterov momentum + coupled weight decay — the phase-1
//! optimizer (the update happens in rust between the gradient all-reduce
//! and the next step). MUST match the fused L1 kernel bit-for-bit-ish:
//!
//! ```text
//! g' = g + wd * p
//! m' = mu * m + g'
//! p' = p - lr * (g' + mu * m')
//! ```
//!
//! `rust/tests/integration_runtime.rs` asserts host-vs-device parity.

use crate::model::ParamSet;
use crate::tensor::Tensor;
use crate::util::{Error, Result};

/// Optimizer constants (per preset; paper §5.1: mu=0.9, wd=5e-4).
#[derive(Debug, Clone, Copy)]
pub struct SgdConfig {
    pub momentum: f32,
    pub weight_decay: f32,
}

/// SGD state = momentum buffers aligned with the param set.
pub struct SgdOptimizer {
    pub cfg: SgdConfig,
    pub momentum: ParamSet,
}

impl SgdOptimizer {
    pub fn new(cfg: SgdConfig, params: &ParamSet) -> Self {
        SgdOptimizer {
            cfg,
            momentum: params.zeros_like(),
        }
    }

    /// One update step over the full parameter set.
    pub fn step(&mut self, params: &mut ParamSet, grads: &[Tensor], lr: f32) -> Result<()> {
        if grads.len() != params.tensors.len() {
            return Err(Error::shape(format!(
                "sgd: {} grads for {} params",
                grads.len(),
                params.tensors.len()
            )));
        }
        let (mu, wd) = (self.cfg.momentum, self.cfg.weight_decay);
        for ((p, m), g) in params
            .tensors
            .iter_mut()
            .zip(self.momentum.tensors.iter_mut())
            .zip(grads)
        {
            if p.shape() != g.shape() {
                return Err(Error::shape("sgd: grad shape mismatch"));
            }
            let (pd, md, gd) = (p.data_mut(), m.data_mut(), g.data());
            for i in 0..pd.len() {
                let g2 = gd[i] + wd * pd[i];
                let m2 = mu * md[i] + g2;
                pd[i] -= lr * (g2 + mu * m2);
                md[i] = m2;
            }
        }
        Ok(())
    }

    /// Reset momentum (paper: phase transitions restart the schedule; we
    /// keep momentum by default but expose reset for ablations).
    pub fn reset(&mut self) {
        for t in &mut self.momentum.tensors {
            t.fill(0.0);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn one_param(vals: &[f32]) -> ParamSet {
        ParamSet {
            tensors: vec![Tensor::new(vec![vals.len()], vals.to_vec()).unwrap()],
        }
    }

    #[test]
    fn plain_sgd_no_momentum_no_wd() {
        let mut p = one_param(&[1.0, 2.0]);
        let g = vec![Tensor::new(vec![2], vec![0.5, -0.5]).unwrap()];
        let mut opt = SgdOptimizer::new(SgdConfig { momentum: 0.0, weight_decay: 0.0 }, &p);
        opt.step(&mut p, &g, 0.1).unwrap();
        assert!((p.tensors[0].data()[0] - 0.95).abs() < 1e-7);
        assert!((p.tensors[0].data()[1] - 2.05).abs() < 1e-7);
    }

    #[test]
    fn nesterov_first_step_scales_by_one_plus_mu() {
        // m=0: p' = p - lr*(g + mu*g) = p - lr*(1+mu)*g
        let mut p = one_param(&[0.0]);
        let g = vec![Tensor::new(vec![1], vec![1.0]).unwrap()];
        let mut opt = SgdOptimizer::new(SgdConfig { momentum: 0.9, weight_decay: 0.0 }, &p);
        opt.step(&mut p, &g, 0.1).unwrap();
        assert!((p.tensors[0].data()[0] + 0.1 * 1.9).abs() < 1e-7);
        // momentum buffer now holds g
        assert!((opt.momentum.tensors[0].data()[0] - 1.0).abs() < 1e-7);
    }

    #[test]
    fn weight_decay_pulls_toward_zero() {
        let mut p = one_param(&[10.0]);
        let g = vec![Tensor::new(vec![1], vec![0.0]).unwrap()];
        let mut opt = SgdOptimizer::new(SgdConfig { momentum: 0.0, weight_decay: 0.1 }, &p);
        opt.step(&mut p, &g, 0.5).unwrap();
        // g' = 0 + 0.1*10 = 1; p' = 10 - 0.5*1 = 9.5
        assert!((p.tensors[0].data()[0] - 9.5).abs() < 1e-6);
    }

    #[test]
    fn matches_scalar_reference_sequence() {
        // hand-rolled 3-step reference with mu=0.9 wd=0.01 lr=0.2
        let (mu, wd, lr) = (0.9f32, 0.01f32, 0.2f32);
        let grads = [0.3f32, -0.1, 0.05];
        let (mut pr, mut mr) = (1.0f32, 0.0f32);
        for g in grads {
            let g2 = g + wd * pr;
            let m2 = mu * mr + g2;
            pr -= lr * (g2 + mu * m2);
            mr = m2;
        }
        let mut p = one_param(&[1.0]);
        let mut opt = SgdOptimizer::new(SgdConfig { momentum: mu, weight_decay: wd }, &p);
        for g in grads {
            let gt = vec![Tensor::new(vec![1], vec![g]).unwrap()];
            opt.step(&mut p, &gt, lr).unwrap();
        }
        assert!((p.tensors[0].data()[0] - pr).abs() < 1e-6);
        assert!((opt.momentum.tensors[0].data()[0] - mr).abs() < 1e-6);
    }

    #[test]
    fn reset_zeroes_momentum() {
        let mut p = one_param(&[1.0]);
        let g = vec![Tensor::new(vec![1], vec![1.0]).unwrap()];
        let mut opt = SgdOptimizer::new(SgdConfig { momentum: 0.9, weight_decay: 0.0 }, &p);
        opt.step(&mut p, &g, 0.1).unwrap();
        opt.reset();
        assert_eq!(opt.momentum.tensors[0].data(), &[0.0]);
    }

    #[test]
    fn shape_mismatch_errors() {
        let mut p = one_param(&[1.0, 2.0]);
        let bad = vec![Tensor::new(vec![3], vec![0.0; 3]).unwrap()];
        let mut opt = SgdOptimizer::new(SgdConfig { momentum: 0.9, weight_decay: 0.0 }, &p);
        assert!(opt.step(&mut p, &bad, 0.1).is_err());
    }
}
