//! Host-side SGD + Nesterov momentum + coupled weight decay — the phase-1
//! optimizer (the update happens in rust between the gradient all-reduce
//! and the next step). MUST match the fused L1 kernel bit-for-bit-ish:
//!
//! ```text
//! g' = g + wd * p
//! m' = mu * m + g'
//! p' = p - lr * (g' + mu * m')
//! ```
//!
//! Since the flat-arena refactor the update is ONE fused pass over the
//! contiguous parameter/momentum/gradient arenas (`tensor::flat::sgd_step`)
//! instead of a per-tensor scalar loop — same elementwise order, bitwise
//! identical, and chunk-parallelizable (`step_mt`).
//!
//! `rust/tests/integration_runtime.rs` asserts host-vs-device parity.

use crate::model::ParamSet;
use crate::tensor::flat;
use crate::util::{Error, Result};

/// Optimizer constants (per preset; paper §5.1: mu=0.9, wd=5e-4).
#[derive(Debug, Clone, Copy)]
pub struct SgdConfig {
    pub momentum: f32,
    pub weight_decay: f32,
}

/// SGD state = one flat momentum arena aligned with the param arena.
pub struct SgdOptimizer {
    pub cfg: SgdConfig,
    pub momentum: ParamSet,
}

impl SgdOptimizer {
    pub fn new(cfg: SgdConfig, params: &ParamSet) -> Self {
        SgdOptimizer {
            cfg,
            momentum: params.zeros_like(),
        }
    }

    /// One update step over the full parameter arena (sequential).
    pub fn step(&mut self, params: &mut ParamSet, grads: &[f32], lr: f32) -> Result<()> {
        self.step_mt(params, grads, lr, 1)
    }

    /// Chunk-parallel update; bitwise identical for every thread count.
    pub fn step_mt(
        &mut self,
        params: &mut ParamSet,
        grads: &[f32],
        lr: f32,
        threads: usize,
    ) -> Result<()> {
        if grads.len() != params.numel() {
            return Err(Error::shape(format!(
                "sgd: {} gradient elements for {} params",
                grads.len(),
                params.numel()
            )));
        }
        if self.momentum.numel() != params.numel() {
            return Err(Error::shape(format!(
                "sgd: momentum has {} elements for {} params",
                self.momentum.numel(),
                params.numel()
            )));
        }
        flat::sgd_step(
            threads,
            params.as_mut_slice(),
            self.momentum.as_mut_slice(),
            grads,
            lr,
            self.cfg.momentum,
            self.cfg.weight_decay,
        );
        Ok(())
    }

    /// Reset momentum (paper: phase transitions restart the schedule; we
    /// keep momentum by default but expose reset for ablations).
    pub fn reset(&mut self) {
        self.momentum.fill(0.0);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn one_param(vals: &[f32]) -> ParamSet {
        ParamSet::from_vec(vals.to_vec())
    }

    #[test]
    fn plain_sgd_no_momentum_no_wd() {
        let mut p = one_param(&[1.0, 2.0]);
        let g = vec![0.5f32, -0.5];
        let mut opt = SgdOptimizer::new(SgdConfig { momentum: 0.0, weight_decay: 0.0 }, &p);
        opt.step(&mut p, &g, 0.1).unwrap();
        assert!((p.data()[0] - 0.95).abs() < 1e-7);
        assert!((p.data()[1] - 2.05).abs() < 1e-7);
    }

    #[test]
    fn nesterov_first_step_scales_by_one_plus_mu() {
        // m=0: p' = p - lr*(g + mu*g) = p - lr*(1+mu)*g
        let mut p = one_param(&[0.0]);
        let g = vec![1.0f32];
        let mut opt = SgdOptimizer::new(SgdConfig { momentum: 0.9, weight_decay: 0.0 }, &p);
        opt.step(&mut p, &g, 0.1).unwrap();
        assert!((p.data()[0] + 0.1 * 1.9).abs() < 1e-7);
        // momentum buffer now holds g
        assert!((opt.momentum.data()[0] - 1.0).abs() < 1e-7);
    }

    #[test]
    fn weight_decay_pulls_toward_zero() {
        let mut p = one_param(&[10.0]);
        let g = vec![0.0f32];
        let mut opt = SgdOptimizer::new(SgdConfig { momentum: 0.0, weight_decay: 0.1 }, &p);
        opt.step(&mut p, &g, 0.5).unwrap();
        // g' = 0 + 0.1*10 = 1; p' = 10 - 0.5*1 = 9.5
        assert!((p.data()[0] - 9.5).abs() < 1e-6);
    }

    #[test]
    fn matches_scalar_reference_sequence() {
        // hand-rolled 3-step reference with mu=0.9 wd=0.01 lr=0.2
        let (mu, wd, lr) = (0.9f32, 0.01f32, 0.2f32);
        let grads = [0.3f32, -0.1, 0.05];
        let (mut pr, mut mr) = (1.0f32, 0.0f32);
        for g in grads {
            let g2 = g + wd * pr;
            let m2 = mu * mr + g2;
            pr -= lr * (g2 + mu * m2);
            mr = m2;
        }
        let mut p = one_param(&[1.0]);
        let mut opt = SgdOptimizer::new(SgdConfig { momentum: mu, weight_decay: wd }, &p);
        for g in grads {
            opt.step(&mut p, &[g], lr).unwrap();
        }
        assert!((p.data()[0] - pr).abs() < 1e-6);
        assert!((opt.momentum.data()[0] - mr).abs() < 1e-6);
    }

    #[test]
    fn parallel_step_bitwise_equals_sequential() {
        // crosses the spawn gate (6n > MIN_ITEM_WORK)
        let n = 200_003;
        let g: Vec<f32> = (0..n).map(|i| (i as f32 * 0.17).sin()).collect();
        let init: Vec<f32> = (0..n).map(|i| (i as f32 * 0.29).cos()).collect();
        let cfg = SgdConfig { momentum: 0.9, weight_decay: 5e-4 };
        let mut p1 = ParamSet::from_vec(init.clone());
        let mut o1 = SgdOptimizer::new(cfg, &p1);
        o1.step(&mut p1, &g, 0.05).unwrap();
        for threads in [2, 4] {
            let mut p2 = ParamSet::from_vec(init.clone());
            let mut o2 = SgdOptimizer::new(cfg, &p2);
            o2.step_mt(&mut p2, &g, 0.05, threads).unwrap();
            assert_eq!(p1, p2, "threads={threads}");
            assert_eq!(o1.momentum, o2.momentum, "threads={threads}");
        }
    }

    #[test]
    fn reset_zeroes_momentum() {
        let mut p = one_param(&[1.0]);
        let g = vec![1.0f32];
        let mut opt = SgdOptimizer::new(SgdConfig { momentum: 0.9, weight_decay: 0.0 }, &p);
        opt.step(&mut p, &g, 0.1).unwrap();
        opt.reset();
        assert_eq!(opt.momentum.data(), &[0.0]);
    }

    #[test]
    fn shape_mismatch_errors() {
        let mut p = one_param(&[1.0, 2.0]);
        let bad = vec![0.0f32; 3];
        let mut opt = SgdOptimizer::new(SgdConfig { momentum: 0.9, weight_decay: 0.0 }, &p);
        assert!(opt.step(&mut p, &bad, 0.1).is_err());
    }
}
