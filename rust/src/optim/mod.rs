//! Optimizers and learning-rate schedules.

pub mod schedule;
pub mod sgd;

pub use schedule::{imagenet_piecewise, Schedule};
pub use sgd::{SgdConfig, SgdOptimizer};
