//! Learning-rate schedules, indexed in steps.
//!
//! The paper uses (Appendix A / §5.2 / §5.3):
//!   * warmup-triangle ("one-cycle") for the CIFAR runs: linear 0 → peak
//!     over the warmup, then linear peak → 0 at the end of training;
//!   * a piecewise-linear multi-phase schedule for ImageNet (Fig 5), which
//!     SWAP composes: doubled schedule in phase 1, original in phase 2;
//!   * cyclic (sawtooth) schedules for SWA (Fig 6), sampling a model at the
//!     end of each cycle where the LR is lowest.
//!
//! `Schedule::series` emits the full LR-vs-step curve — that is exactly the
//! data Figures 5 and 6 plot.

/// A learning-rate schedule over integer steps.
#[derive(Debug, Clone, PartialEq)]
pub enum Schedule {
    Constant(f32),
    /// Linear 0→peak over `warmup`, then peak→`end_lr` over the rest.
    Triangle {
        peak: f32,
        warmup: usize,
        total: usize,
        end_lr: f32,
    },
    /// Linear interpolation between (step, lr) breakpoints; clamped at the
    /// ends. Breakpoints must be strictly increasing in step.
    Piecewise(Vec<(usize, f32)>),
    /// Linear warmup 0→peak over `warmup` steps, then half-cosine decay
    /// peak→`end_lr` until `total`; clamped at `end_lr` afterwards (the
    /// standard warmup-cosine schedule, an SWA/large-batch staple).
    Cosine {
        peak: f32,
        warmup: usize,
        total: usize,
        end_lr: f32,
    },
    /// Sawtooth cycles for SWA: within each cycle of `period` steps the LR
    /// decays linearly high→low, then jumps back to high.
    Cyclic {
        high: f32,
        low: f32,
        period: usize,
    },
    /// Schedules run back to back, each for its `len` steps; steps beyond
    /// the last segment keep the last segment's final value.
    Sequence(Vec<(usize, Schedule)>),
}

impl Schedule {
    pub fn lr(&self, step: usize) -> f32 {
        match self {
            Schedule::Constant(v) => *v,
            Schedule::Triangle { peak, warmup, total, end_lr } => {
                let s = step.min(*total) as f32;
                let (w, t) = (*warmup as f32, *total as f32);
                if s < w {
                    peak * s / w.max(1.0)
                } else if t > w {
                    peak + (end_lr - peak) * (s - w) / (t - w)
                } else {
                    *peak
                }
            }
            Schedule::Piecewise(points) => {
                debug_assert!(!points.is_empty());
                if step <= points[0].0 {
                    return points[0].1;
                }
                for win in points.windows(2) {
                    let ((s0, l0), (s1, l1)) = (win[0], win[1]);
                    if step <= s1 {
                        let t = (step - s0) as f32 / (s1 - s0).max(1) as f32;
                        return l0 + (l1 - l0) * t;
                    }
                }
                points.last().unwrap().1
            }
            Schedule::Cosine { peak, warmup, total, end_lr } => {
                let s = step.min(*total) as f32;
                let t = *total as f32;
                // warmup longer than the schedule would otherwise cap lr
                // below peak forever and never reach end_lr
                let w = (*warmup as f32).min(t);
                if s < w {
                    peak * s / w.max(1.0)
                } else if t > w {
                    let frac = ((s - w) / (t - w)).clamp(0.0, 1.0);
                    end_lr + (peak - end_lr) * 0.5 * (1.0 + (std::f32::consts::PI * frac).cos())
                } else {
                    *peak
                }
            }
            Schedule::Cyclic { high, low, period } => {
                let pos = (step % period.max(&1)) as f32;
                let frac = pos / (*period as f32 - 1.0).max(1.0);
                high + (low - high) * frac
            }
            Schedule::Sequence(parts) => {
                let mut s = step;
                for (i, (len, sched)) in parts.iter().enumerate() {
                    if s < *len || i == parts.len() - 1 {
                        return sched.lr(s.min(len.saturating_sub(1)));
                    }
                    s -= len;
                }
                0.0
            }
        }
    }

    /// Full curve for plotting (Figures 1, 5, 6).
    pub fn series(&self, steps: usize) -> Vec<f32> {
        (0..steps).map(|s| self.lr(s)).collect()
    }

    /// Steps within a cyclic schedule where SWA samples a model (end of
    /// each cycle — the low-LR point).
    pub fn cycle_ends(period: usize, total: usize) -> Vec<usize> {
        (1..=total / period).map(|k| k * period - 1).collect()
    }

    /// Scale all learning rates by `k` (the paper's linear-scaling rule:
    /// double the batch → double the LR, §5.2).
    pub fn scaled(&self, k: f32) -> Schedule {
        match self {
            Schedule::Constant(v) => Schedule::Constant(v * k),
            Schedule::Triangle { peak, warmup, total, end_lr } => Schedule::Triangle {
                peak: peak * k,
                warmup: *warmup,
                total: *total,
                end_lr: end_lr * k,
            },
            Schedule::Piecewise(pts) => {
                Schedule::Piecewise(pts.iter().map(|(s, l)| (*s, l * k)).collect())
            }
            Schedule::Cosine { peak, warmup, total, end_lr } => Schedule::Cosine {
                peak: peak * k,
                warmup: *warmup,
                total: *total,
                end_lr: end_lr * k,
            },
            Schedule::Cyclic { high, low, period } => Schedule::Cyclic {
                high: high * k,
                low: low * k,
                period: *period,
            },
            Schedule::Sequence(parts) => Schedule::Sequence(
                parts.iter().map(|(n, s)| (*n, s.scaled(k))).collect(),
            ),
        }
    }
}

/// The DAWNBench-style ImageNet schedule of Fig 5 (original, 8-GPU form),
/// expressed in steps given `steps_per_epoch`. LR breakpoints follow the
/// published shape: warmup, high plateau decaying in drops toward zero.
pub fn imagenet_piecewise(steps_per_epoch: usize, peak: f32) -> Schedule {
    let e = |x: f64| (x * steps_per_epoch as f64) as usize;
    Schedule::Piecewise(vec![
        (0, peak * 0.25),
        (e(4.0), peak),         // warmup to peak by epoch 4
        (e(18.0), peak * 0.1),  // long decay
        (e(25.0), peak * 0.01), // drop
        (e(28.0), peak * 0.001),
    ])
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn constant_is_constant() {
        let s = Schedule::Constant(0.3);
        assert_eq!(s.lr(0), 0.3);
        assert_eq!(s.lr(10_000), 0.3);
    }

    #[test]
    fn triangle_warmup_and_decay() {
        let s = Schedule::Triangle { peak: 1.0, warmup: 10, total: 30, end_lr: 0.0 };
        assert_eq!(s.lr(0), 0.0);
        assert!((s.lr(5) - 0.5).abs() < 1e-6);
        assert!((s.lr(10) - 1.0).abs() < 1e-6);
        assert!((s.lr(20) - 0.5).abs() < 1e-6);
        assert!(s.lr(30).abs() < 1e-6);
        assert!(s.lr(99).abs() < 1e-6); // clamped past the end
    }

    #[test]
    fn triangle_monotone_up_then_down() {
        let s = Schedule::Triangle { peak: 0.4, warmup: 7, total: 31, end_lr: 0.0 };
        for t in 0..6 {
            assert!(s.lr(t + 1) >= s.lr(t));
        }
        for t in 8..30 {
            assert!(s.lr(t + 1) <= s.lr(t));
        }
        assert!(s.series(31).iter().all(|&l| l >= 0.0));
    }

    #[test]
    fn cosine_warmup_decay_shape() {
        let s = Schedule::Cosine { peak: 1.0, warmup: 10, total: 50, end_lr: 0.1 };
        assert_eq!(s.lr(0), 0.0);
        assert!((s.lr(5) - 0.5).abs() < 1e-6);
        assert!((s.lr(10) - 1.0).abs() < 1e-6);
        // halfway through the decay: mean of peak and end
        assert!((s.lr(30) - 0.55).abs() < 1e-4);
        assert!((s.lr(50) - 0.1).abs() < 1e-6);
        assert!((s.lr(500) - 0.1).abs() < 1e-6); // clamped past the end
        // monotone up through warmup, down through decay
        for t in 0..9 {
            assert!(s.lr(t + 1) >= s.lr(t));
        }
        for t in 10..49 {
            assert!(s.lr(t + 1) <= s.lr(t));
        }
        // scaling scales both ends
        let d = s.scaled(2.0);
        assert!((d.lr(10) - 2.0).abs() < 1e-6);
        assert!((d.lr(50) - 0.2).abs() < 1e-6);
        // degenerate warmup > total: clamped so peak is still reached
        let g = Schedule::Cosine { peak: 1.0, warmup: 10, total: 5, end_lr: 0.0 };
        assert!((g.lr(2) - 0.4).abs() < 1e-6);
        assert!((g.lr(5) - 1.0).abs() < 1e-6);
        assert!((g.lr(100) - 1.0).abs() < 1e-6);
    }

    #[test]
    fn piecewise_interpolates_and_clamps() {
        let s = Schedule::Piecewise(vec![(0, 0.1), (10, 1.0), (20, 0.0)]);
        assert!((s.lr(5) - 0.55).abs() < 1e-6);
        assert!((s.lr(10) - 1.0).abs() < 1e-6);
        assert!((s.lr(15) - 0.5).abs() < 1e-6);
        assert_eq!(s.lr(100), 0.0);
    }

    #[test]
    fn cyclic_sawtooth() {
        let s = Schedule::Cyclic { high: 1.0, low: 0.1, period: 10 };
        assert_eq!(s.lr(0), 1.0);
        assert!((s.lr(9) - 0.1).abs() < 1e-6); // end of cycle = low
        assert_eq!(s.lr(10), 1.0); // jumps back
        assert!((s.lr(19) - 0.1).abs() < 1e-6);
    }

    #[test]
    fn cycle_ends_are_low_points() {
        let ends = Schedule::cycle_ends(10, 35);
        assert_eq!(ends, vec![9, 19, 29]);
        let s = Schedule::Cyclic { high: 1.0, low: 0.05, period: 10 };
        for e in ends {
            assert!((s.lr(e) - 0.05).abs() < 1e-6);
        }
    }

    #[test]
    fn sequence_concatenates_and_holds_tail() {
        let s = Schedule::Sequence(vec![
            (10, Schedule::Constant(1.0)),
            (10, Schedule::Triangle { peak: 0.5, warmup: 0, total: 10, end_lr: 0.0 }),
        ]);
        assert_eq!(s.lr(3), 1.0);
        assert!((s.lr(10) - 0.5).abs() < 1e-6);
        assert!(s.lr(19) < 0.1);
        // past the end: holds last segment's final value
        assert_eq!(s.lr(500), s.lr(19));
    }

    #[test]
    fn scaled_doubles_everything() {
        let s = Schedule::Triangle { peak: 0.6, warmup: 5, total: 20, end_lr: 0.0 }.scaled(2.0);
        assert!((s.lr(5) - 1.2).abs() < 1e-6);
        let p = imagenet_piecewise(100, 1.0).scaled(2.0);
        assert!((p.lr(400) - 2.0).abs() < 1e-5);
    }

    #[test]
    fn imagenet_shape() {
        let s = imagenet_piecewise(100, 1.0);
        assert!(s.lr(0) < s.lr(400)); // warms up
        assert!(s.lr(400) > s.lr(1800)); // decays
        assert!(s.lr(2800) <= 0.0011); // tiny at the end
        assert!(s.series(2800).iter().all(|&l| l > 0.0));
    }

    #[test]
    fn nonnegative_everywhere_property() {
        crate::testutil::property(100, |g| {
            let sched = match g.usize_in(0..4) {
                0 => Schedule::Constant(g.f32_in(0.0..2.0)),
                1 => Schedule::Triangle {
                    peak: g.f32_in(0.01..2.0),
                    warmup: g.usize_in(1..50),
                    total: g.usize_in(50..200),
                    end_lr: 0.0,
                },
                2 => Schedule::Cyclic {
                    high: g.f32_in(0.5..2.0),
                    low: g.f32_in(0.0..0.5),
                    period: g.usize_in(2..40),
                },
                _ => Schedule::Piecewise(vec![
                    (0, g.f32_in(0.0..1.0)),
                    (g.usize_in(1..50), g.f32_in(0.0..1.0)),
                    (g.usize_in(50..100), g.f32_in(0.0..1.0)),
                ]),
            };
            for step in 0..250 {
                let lr = sched.lr(step);
                assert!(lr >= 0.0 && lr.is_finite(), "lr {lr} at {step} in {sched:?}");
            }
        });
    }
}
