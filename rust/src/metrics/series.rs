//! Column-oriented series log → CSV. Every figure bench writes one of
//! these under results/ so the curves can be re-plotted externally.

use std::path::Path;

use crate::util::{Error, Result};

/// A table of f64 columns with string headers, row-appended.
#[derive(Debug, Clone)]
pub struct SeriesLog {
    headers: Vec<String>,
    rows: Vec<Vec<f64>>,
}

impl SeriesLog {
    pub fn new(headers: &[&str]) -> Self {
        SeriesLog {
            headers: headers.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn push(&mut self, row: &[f64]) {
        assert_eq!(row.len(), self.headers.len(), "ragged series row");
        self.rows.push(row.to_vec());
    }

    pub fn len(&self) -> usize {
        self.rows.len()
    }

    pub fn is_empty(&self) -> bool {
        self.rows.is_empty()
    }

    pub fn headers(&self) -> &[String] {
        &self.headers
    }

    /// Column by header name.
    pub fn column(&self, name: &str) -> Option<Vec<f64>> {
        let i = self.headers.iter().position(|h| h == name)?;
        Some(self.rows.iter().map(|r| r[i]).collect())
    }

    pub fn to_csv(&self) -> String {
        let mut out = self.headers.join(",");
        out.push('\n');
        for row in &self.rows {
            let cells: Vec<String> = row.iter().map(|v| format!("{v}")).collect();
            out.push_str(&cells.join(","));
            out.push('\n');
        }
        out
    }

    pub fn write_csv(&self, path: impl AsRef<Path>) -> Result<()> {
        if let Some(parent) = path.as_ref().parent() {
            std::fs::create_dir_all(parent)?;
        }
        std::fs::write(path.as_ref(), self.to_csv()).map_err(Error::Io)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn push_and_column() {
        let mut s = SeriesLog::new(&["step", "acc"]);
        s.push(&[0.0, 0.5]);
        s.push(&[1.0, 0.75]);
        assert_eq!(s.len(), 2);
        assert_eq!(s.column("acc").unwrap(), vec![0.5, 0.75]);
        assert!(s.column("nope").is_none());
    }

    #[test]
    fn csv_format() {
        let mut s = SeriesLog::new(&["a", "b"]);
        s.push(&[1.0, 2.5]);
        assert_eq!(s.to_csv(), "a,b\n1,2.5\n");
    }

    #[test]
    #[should_panic(expected = "ragged")]
    fn ragged_row_panics() {
        let mut s = SeriesLog::new(&["a"]);
        s.push(&[1.0, 2.0]);
    }

    #[test]
    fn write_csv_creates_dirs() {
        let dir = std::env::temp_dir().join(format!("swap-series-{}", std::process::id()));
        let path = dir.join("sub/fig.csv");
        let mut s = SeriesLog::new(&["x"]);
        s.push(&[7.0]);
        s.write_csv(&path).unwrap();
        assert!(std::fs::read_to_string(&path).unwrap().contains("7"));
        std::fs::remove_dir_all(&dir).ok();
    }
}
