//! Metrics: training curves (CSV series for the figures) and
//! across-run summaries (mean ± std for the tables).

pub mod series;

pub use series::SeriesLog;

use crate::bench::{stats, Stats};

/// Accuracy/time outcome of one experiment run.
#[derive(Debug, Clone, Copy, Default)]
pub struct RunOutcome {
    pub test_acc1: f64,
    pub test_acc5: f64,
    pub test_loss: f64,
    /// modeled cluster seconds (the paper's "training time")
    pub cluster_seconds: f64,
    /// real wall seconds on this machine (reference)
    pub wall_seconds: f64,
}

/// mean ± std of a set of outcomes, field-wise.
#[derive(Debug, Clone)]
pub struct OutcomeSummary {
    pub acc1: Stats,
    pub acc5: Stats,
    pub loss: Stats,
    pub cluster: Stats,
    pub wall: Stats,
    pub n: usize,
}

pub fn summarize(outs: &[RunOutcome]) -> OutcomeSummary {
    assert!(!outs.is_empty());
    let pick = |f: fn(&RunOutcome) -> f64| stats(&outs.iter().map(f).collect::<Vec<_>>());
    OutcomeSummary {
        acc1: pick(|o| o.test_acc1),
        acc5: pick(|o| o.test_acc5),
        loss: pick(|o| o.test_loss),
        cluster: pick(|o| o.cluster_seconds),
        wall: pick(|o| o.wall_seconds),
        n: outs.len(),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn summarize_means() {
        let outs = [
            RunOutcome { test_acc1: 0.9, cluster_seconds: 10.0, ..Default::default() },
            RunOutcome { test_acc1: 0.8, cluster_seconds: 20.0, ..Default::default() },
        ];
        let s = summarize(&outs);
        assert!((s.acc1.mean - 0.85).abs() < 1e-12);
        assert!((s.cluster.mean - 15.0).abs() < 1e-12);
        assert_eq!(s.n, 2);
    }

    #[test]
    #[should_panic]
    fn summarize_empty_panics() {
        summarize(&[]);
    }
}
