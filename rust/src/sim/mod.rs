//! Virtual-cluster cost model — the documented substitution for the
//! paper's 8-16 V100 + Horovod testbed (DESIGN.md §Hardware-Adaptation).
//!
//! One CPU core cannot run 8 workers concurrently, so every experiment
//! advances a **discrete-event cluster clock**: per-worker compute time
//! comes from a saturating device-throughput model, synchronization costs
//! come from an α–β ring all-reduce model, and phase-2's independent
//! workers advance the clock by the *maximum* of their individual times
//! (they run in parallel on the modeled cluster). Tables 1-4 report this
//! clock; real wall-clock is also recorded for reference.
//!
//! Constants are calibrated so the *ratios* of the paper's Table 1 hold
//! (LB/SB per-epoch speedup ≈ 5.8x on 8 devices vs 1, all-reduce overhead
//! ≈ 27% of an LB step at W=8) — see `v100_like` and the table benches.

pub mod clock;
pub mod device;
pub mod network;

pub use clock::ClusterClock;
pub use device::DeviceModel;
pub use network::NetModel;

/// Host-side input assembly (gather + augment) cost per example — the
/// coordinator work the prefetcher hides behind device compute. Roughly a
/// 3 KB image copy plus flip/shift/cutout on a modern core.
pub const HOST_ASSEMBLY_PER_EXAMPLE: f64 = 5.0e-7;

/// Everything needed to price an experiment on the virtual cluster.
#[derive(Debug, Clone)]
pub struct CostModel {
    pub device: DeviceModel,
    pub net: NetModel,
    /// forward FLOPs per example (from the artifact manifest)
    pub flops_fwd_per_example: u64,
    /// model size in bytes (gradient all-reduce message)
    pub param_bytes: u64,
    /// host batch-assembly seconds per example (input pipeline)
    pub host_assembly_per_example: f64,
}

impl CostModel {
    pub fn new(device: DeviceModel, net: NetModel, manifest: &crate::runtime::Manifest) -> Self {
        CostModel {
            device,
            net,
            flops_fwd_per_example: manifest.flops_fwd_per_example,
            param_bytes: manifest.param_bytes(),
            host_assembly_per_example: HOST_ASSEMBLY_PER_EXAMPLE,
        }
    }

    /// One training step (fwd+bwd ≈ 3x fwd) on one device.
    pub fn train_step_time(&self, per_worker_batch: usize) -> f64 {
        self.device
            .compute_time(per_worker_batch, 3 * self.flops_fwd_per_example)
    }

    /// One evaluation / BN-stat pass (fwd only) on one device.
    pub fn eval_step_time(&self, batch: usize) -> f64 {
        self.device.compute_time(batch, self.flops_fwd_per_example)
    }

    /// Gradient ring all-reduce across `workers` devices.
    pub fn allreduce_time(&self, workers: usize) -> f64 {
        self.net.ring_allreduce(self.param_bytes, workers)
    }

    /// Host input assembly (gather + augment) of one step's `examples`.
    pub fn assembly_time(&self, examples: usize) -> f64 {
        examples as f64 * self.host_assembly_per_example
    }

    /// Weight bytes a real phase-2 transport moves for `workers` workers:
    /// the phase-1 broadcast down to each worker plus each refined replica
    /// uploaded back — 2 × workers × param_bytes. On a zero-drop socket
    /// run the measured `NetStats::param_bytes` must equal this exactly
    /// (asserted in rust/tests/transport.rs).
    pub fn phase2_comm_bytes(&self, workers: usize) -> u64 {
        2 * workers as u64 * self.param_bytes
    }

    /// Weight bytes a zero-failure distributed phase 1 moves: per sync
    /// step the hub broadcasts the weights to each of `members` links and
    /// gathers one same-sized gradient arena per device back. Measured
    /// `NetStats::param_bytes` of a zero-drop collective must equal this
    /// exactly (asserted in rust/tests/transport.rs).
    pub fn phase1_comm_bytes(&self, steps: usize, members: usize, devices: usize) -> u64 {
        steps as u64 * (members + devices) as u64 * self.param_bytes
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::Manifest;
    use std::path::PathBuf;

    fn manifest() -> Manifest {
        Manifest::parse(
            r#"{"preset":"unit",
                "model":{"arch":"resnet9s","width":4,"num_classes":10,"image_size":16,
                         "momentum":0.9,"weight_decay":0.0005,"head_scale":0.125,"bn_eps":1e-05},
                "params":[{"name":"prep.w","shape":[27,4]}],
                "bn_stats":[],
                "num_params":108,"batches":[8],"executables":{},
                "flops_fwd_per_example":12000000}"#,
            PathBuf::new(),
        )
        .unwrap()
    }

    #[test]
    fn cost_model_scales_with_batch_and_workers() {
        let cm = CostModel::new(DeviceModel::v100_like(), NetModel::pcie_like(), &manifest());
        // larger per-worker batch -> more time, but sublinear near saturation
        let t64 = cm.train_step_time(64);
        let t512 = cm.train_step_time(512);
        assert!(t512 > t64 && t512 < 8.5 * t64);
        // more workers -> more all-reduce time
        assert!(cm.allreduce_time(8) > cm.allreduce_time(2));
        // eval cheaper than train
        assert!(cm.eval_step_time(64) < t64);
        // assembly scales linearly and is far cheaper than device compute
        assert_eq!(cm.assembly_time(128), 2.0 * cm.assembly_time(64));
        assert!(cm.assembly_time(64) < cm.train_step_time(64));
        // phase-2 wire traffic: one broadcast down + one upload up per worker
        assert_eq!(cm.phase2_comm_bytes(4), 8 * cm.param_bytes);
        // phase-1 wire traffic: per step, one broadcast per member and one
        // gradient arena per device
        assert_eq!(cm.phase1_comm_bytes(12, 2, 4), 12 * 6 * cm.param_bytes);
    }

    #[test]
    fn paper_ratio_allreduce_overhead() {
        // With paper-scale tensors (6.5M params, 250 MFLOP fwd), the W=8
        // all-reduce should cost roughly 25-50% of a B=512-per-worker step
        // — the overhead implied by Table 1 (see module docs).
        let cm = CostModel {
            device: DeviceModel::v100_like(),
            net: NetModel::pcie_like(),
            flops_fwd_per_example: 250_000_000,
            param_bytes: 26_000_000,
            host_assembly_per_example: HOST_ASSEMBLY_PER_EXAMPLE,
        };
        let ratio = cm.allreduce_time(8) / cm.train_step_time(512);
        assert!((0.2..0.6).contains(&ratio), "allreduce/step = {ratio}");
    }
}
