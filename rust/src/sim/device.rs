//! Saturating device-throughput model.
//!
//! Effective FLOP/s at per-device batch b:  peak * b / (b + b_half) — small
//! batches underutilize the device (kernel launch / pipeline bubbles),
//! large batches approach peak. This is the standard "half-saturation"
//! throughput curve and matches the qualitative batch-size scaling in the
//! paper's Tables 1-3 (per-device batches 64-512 are near saturation).

#[derive(Debug, Clone, Copy)]
pub struct DeviceModel {
    /// saturated throughput in FLOP/s
    pub peak_flops: f64,
    /// batch size at which half of peak is reached
    pub half_batch: f64,
    /// fixed per-step overhead (launch, host sync) in seconds
    pub overhead: f64,
}

impl DeviceModel {
    /// V100-like constants (fp16/tensor-core effective throughput as the
    /// DAWNBench CIFAR submissions achieve it).
    pub fn v100_like() -> Self {
        DeviceModel {
            peak_flops: 15.0e12,
            half_batch: 32.0,
            overhead: 0.3e-3,
        }
    }

    /// Time for `flops_per_example * batch` FLOPs at this batch size.
    pub fn compute_time(&self, batch: usize, flops_per_example: u64) -> f64 {
        let b = batch as f64;
        let eff = self.peak_flops * b / (b + self.half_batch);
        self.overhead + b * flops_per_example as f64 / eff
    }

    /// Samples/sec at a given batch (for reporting).
    pub fn throughput(&self, batch: usize, flops_per_example: u64) -> f64 {
        batch as f64 / self.compute_time(batch, flops_per_example)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn throughput_increases_with_batch() {
        let d = DeviceModel::v100_like();
        let f = 250_000_000u64;
        let t64 = d.throughput(64, f);
        let t512 = d.throughput(512, f);
        assert!(t512 > t64);
        // and saturates: 512 -> 4096 gains less than 2x
        let t4096 = d.throughput(4096, f);
        assert!(t4096 < 2.0 * t512);
    }

    #[test]
    fn compute_time_monotone_in_batch_and_flops() {
        let d = DeviceModel::v100_like();
        assert!(d.compute_time(128, 1_000_000) > d.compute_time(64, 1_000_000));
        assert!(d.compute_time(64, 2_000_000) > d.compute_time(64, 1_000_000));
        assert!(d.compute_time(1, 1) >= d.overhead);
    }

    #[test]
    fn v100_ballpark() {
        // ~512-batch ResNet9 step (750 MFLOP/example fwd+bwd) should be
        // tens of milliseconds — the DAWNBench regime.
        let d = DeviceModel::v100_like();
        let t = d.compute_time(512, 750_000_000);
        assert!((0.01..0.1).contains(&t), "step time {t}");
    }
}
