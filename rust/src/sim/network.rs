//! α–β ring all-reduce cost model (Horovod-style).
//!
//! A ring all-reduce of S bytes over W workers moves 2·(W-1)/W · S bytes
//! through each link in 2·(W-1) latency-bound phases:
//!
//! ```text
//! T = 2 (W-1) α  +  2 (W-1)/W · S / β
//! ```
//!
//! with α the per-message latency and β the link bandwidth. W=1 is free.

#[derive(Debug, Clone, Copy)]
pub struct NetModel {
    /// per-message latency α in seconds
    pub latency: f64,
    /// link bandwidth β in bytes/sec
    pub bandwidth: f64,
}

impl NetModel {
    /// PCIe/early-NCCL-era constants; calibrated so the W=8 all-reduce of a
    /// 26 MB ResNet9 gradient costs ~25-40% of a 512-per-worker V100 step,
    /// the overhead Table 1 implies (see sim::tests).
    pub fn pcie_like() -> Self {
        NetModel {
            latency: 50e-6,
            bandwidth: 5.0e9,
        }
    }

    /// NVLink-like (for ablations: what if the interconnect were faster?).
    pub fn nvlink_like() -> Self {
        NetModel {
            latency: 10e-6,
            bandwidth: 60.0e9,
        }
    }

    pub fn ring_allreduce(&self, bytes: u64, workers: usize) -> f64 {
        if workers <= 1 {
            return 0.0;
        }
        let w = workers as f64;
        2.0 * (w - 1.0) * self.latency + 2.0 * (w - 1.0) / w * bytes as f64 / self.bandwidth
    }

    /// One sync step of the *distributed* phase-1 collective as the
    /// socket hub executes it: a serial weight broadcast of `bytes` to
    /// each of `members` links, then `devices` gradient uploads of the
    /// same size gathered back — (members + devices) frames through one
    /// host, each paying latency plus serialization. This is the measured
    /// topology of `serve_phase1`, validated against loopback wall clock
    /// in rust/benches/transport.rs.
    pub fn hub_exchange(&self, bytes: u64, members: usize, devices: usize) -> f64 {
        (members + devices) as f64 * (self.latency + bytes as f64 / self.bandwidth)
    }

    /// Broadcast of the model (phase transitions): one tree pass.
    pub fn broadcast(&self, bytes: u64, workers: usize) -> f64 {
        if workers <= 1 {
            return 0.0;
        }
        let hops = (workers as f64).log2().ceil();
        hops * (self.latency + bytes as f64 / self.bandwidth)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn single_worker_is_free() {
        let n = NetModel::pcie_like();
        assert_eq!(n.ring_allreduce(1 << 30, 1), 0.0);
        assert_eq!(n.broadcast(1 << 30, 1), 0.0);
    }

    #[test]
    fn monotone_in_workers_and_bytes() {
        let n = NetModel::pcie_like();
        assert!(n.ring_allreduce(1 << 20, 8) > n.ring_allreduce(1 << 20, 2));
        assert!(n.ring_allreduce(1 << 24, 8) > n.ring_allreduce(1 << 20, 8));
    }

    #[test]
    fn bandwidth_term_dominates_large_messages() {
        let n = NetModel::pcie_like();
        let t = n.ring_allreduce(26_000_000, 8);
        let bw_term = 2.0 * 7.0 / 8.0 * 26e6 / n.bandwidth;
        assert!(t > bw_term && t < bw_term * 1.2, "t={t} bw={bw_term}");
    }

    #[test]
    fn hub_exchange_scales_with_fanout_and_bytes() {
        let n = NetModel::pcie_like();
        assert!(n.hub_exchange(1 << 20, 4, 8) > n.hub_exchange(1 << 20, 2, 4));
        assert!(n.hub_exchange(1 << 24, 2, 4) > n.hub_exchange(1 << 20, 2, 4));
        // members == devices (group_devices = 1): down + up per member
        let one = n.latency + (1 << 20) as f64 / n.bandwidth;
        assert!((n.hub_exchange(1 << 20, 3, 3) - 6.0 * one).abs() < 1e-12);
    }

    #[test]
    fn nvlink_faster_than_pcie() {
        let a = NetModel::pcie_like().ring_allreduce(26_000_000, 8);
        let b = NetModel::nvlink_like().ring_allreduce(26_000_000, 8);
        assert!(b < a / 5.0);
    }
}
