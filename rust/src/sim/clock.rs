//! Discrete-event cluster clock with a compute/communication/data
//! breakdown.
//!
//! Phase 1 advances by `compute + allreduce` per synchronous step; phase 2
//! advances by the slowest per-worker clock via `advance_parallel`, which
//! absorbs that worker's own compute/comm/data breakdown. Input-pipeline
//! (batch assembly) time is booked via `note_data`: when the prefetcher
//! overlaps assembly with the device step it hides behind compute
//! (`data_hidden`, not on the critical path); serial assembly — or the
//! part of an oversized assembly that compute cannot cover — lands in
//! `data_exposed` and extends `seconds`. Evaluation passes are tracked
//! separately and do NOT count toward training time (the paper's tables
//! report training time).

#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct ClusterClock {
    /// modeled training seconds
    pub seconds: f64,
    /// breakdown: device compute
    pub compute: f64,
    /// breakdown: communication (all-reduce, broadcast)
    pub comm: f64,
    /// input assembly hidden behind device work (prefetch overlap; NOT
    /// part of `seconds`)
    pub data_hidden: f64,
    /// input assembly exposed on the critical path (part of `seconds`)
    pub data_exposed: f64,
    /// modeled evaluation seconds (reported, not part of `seconds`)
    pub eval: f64,
    /// modeled seconds burned by phase-2 workers that were dropped from
    /// the average (reported, not part of `seconds` — the surviving
    /// cluster never waits on a dropped worker)
    pub lost: f64,
}

impl ClusterClock {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn advance_compute(&mut self, dt: f64) {
        debug_assert!(dt >= 0.0);
        self.seconds += dt;
        self.compute += dt;
    }

    pub fn advance_comm(&mut self, dt: f64) {
        debug_assert!(dt >= 0.0);
        self.seconds += dt;
        self.comm += dt;
    }

    /// Book one step's input-assembly time against a device-work budget.
    /// `overlapped` (the prefetching pipeline): up to `budget` seconds
    /// hide behind the device step, only the excess reaches the critical
    /// path. Serial input: the full `dt` is exposed. The accounting
    /// follows the *configured* pipeline, never the host's execution
    /// strategy, so the modeled clock is identical for every thread count.
    pub fn note_data(&mut self, dt: f64, budget: f64, overlapped: bool) {
        debug_assert!(dt >= 0.0 && budget >= 0.0);
        let exposed = if overlapped {
            let hidden = dt.min(budget);
            self.data_hidden += hidden;
            dt - hidden
        } else {
            dt
        };
        if exposed > 0.0 {
            self.data_exposed += exposed;
            self.seconds += exposed;
        }
    }

    /// Advance by the slowest of parallel worker clocks (phase 2: the
    /// cluster waits for all independent workers to finish). The slowest
    /// worker's own compute/comm breakdown is absorbed — booking its total
    /// as pure compute would lose the communication component whenever a
    /// phase-2 group is itself data-parallel (`group_devices > 1`).
    /// Evaluation seconds are summed over all workers (eval is reported,
    /// never part of training `seconds`).
    pub fn advance_parallel(&mut self, workers: &[ClusterClock]) {
        if let Some(slowest) = workers
            .iter()
            .max_by(|a, b| a.seconds.total_cmp(&b.seconds))
        {
            debug_assert!(slowest.seconds >= 0.0);
            self.seconds += slowest.seconds;
            self.compute += slowest.compute;
            self.comm += slowest.comm;
            self.data_hidden += slowest.data_hidden;
            self.data_exposed += slowest.data_exposed;
        }
        for w in workers {
            self.eval += w.eval;
            self.lost += w.lost;
        }
    }

    pub fn note_eval(&mut self, dt: f64) {
        self.eval += dt;
    }

    /// Book the modeled time a dropped phase-2 worker wasted. The drop
    /// changes which replicas are averaged, never the survivors' critical
    /// path, so `seconds` is untouched.
    pub fn note_drop(&mut self, modeled_seconds: f64) {
        debug_assert!(modeled_seconds >= 0.0);
        self.lost += modeled_seconds;
    }

    /// Merge a sub-phase clock (e.g. a worker's own clock) serially.
    pub fn absorb(&mut self, other: &ClusterClock) {
        self.seconds += other.seconds;
        self.compute += other.compute;
        self.comm += other.comm;
        self.data_hidden += other.data_hidden;
        self.data_exposed += other.data_exposed;
        self.eval += other.eval;
        self.lost += other.lost;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn advances_accumulate() {
        let mut c = ClusterClock::new();
        c.advance_compute(1.0);
        c.advance_comm(0.5);
        assert_eq!(c.seconds, 1.5);
        assert_eq!(c.compute, 1.0);
        assert_eq!(c.comm, 0.5);
    }

    #[test]
    fn parallel_takes_max() {
        let worker = |compute: f64, comm: f64| {
            let mut w = ClusterClock::new();
            w.advance_compute(compute);
            w.advance_comm(comm);
            w
        };
        let mut c = ClusterClock::new();
        c.advance_parallel(&[worker(1.0, 0.0), worker(2.0, 1.0), worker(2.0, 0.0)]);
        assert_eq!(c.seconds, 3.0);
        c.advance_parallel(&[]);
        assert_eq!(c.seconds, 3.0);
    }

    #[test]
    fn parallel_keeps_comm_breakdown() {
        // the slowest worker's compute/comm split must survive (a phase-2
        // group with group_devices > 1 pays all-reduce time every step)
        let mut slow = ClusterClock::new();
        slow.advance_compute(4.0);
        slow.advance_comm(2.0);
        let mut fast = ClusterClock::new();
        fast.advance_compute(1.0);
        fast.note_eval(0.5);
        let mut c = ClusterClock::new();
        c.advance_compute(10.0); // phase 1
        c.advance_parallel(&[fast, slow]);
        assert_eq!(c.seconds, 16.0);
        assert_eq!(c.compute, 14.0);
        assert_eq!(c.comm, 2.0);
        // eval sums over all workers, outside training time
        assert_eq!(c.eval, 0.5);
    }

    #[test]
    fn data_time_hidden_vs_exposed() {
        // serial input: fully on the critical path
        let mut serial = ClusterClock::new();
        serial.advance_compute(1.0);
        serial.note_data(0.2, 1.0, false);
        assert_eq!(serial.seconds, 1.2);
        assert_eq!(serial.data_exposed, 0.2);
        assert_eq!(serial.data_hidden, 0.0);

        // prefetched input that fits the budget: entirely hidden
        let mut pre = ClusterClock::new();
        pre.advance_compute(1.0);
        pre.note_data(0.2, 1.0, true);
        assert_eq!(pre.seconds, 1.0);
        assert_eq!(pre.data_hidden, 0.2);
        assert_eq!(pre.data_exposed, 0.0);

        // oversized assembly: only the excess is exposed
        let mut big = ClusterClock::new();
        big.advance_compute(1.0);
        big.note_data(1.5, 1.0, true);
        assert_eq!(big.seconds, 1.5);
        assert_eq!(big.data_hidden, 1.0);
        assert_eq!(big.data_exposed, 0.5);
    }

    #[test]
    fn parallel_and_absorb_carry_data_breakdown() {
        let mut w = ClusterClock::new();
        w.advance_compute(2.0);
        w.note_data(0.5, 2.0, true);
        w.note_data(0.3, 0.0, false);
        let mut c = ClusterClock::new();
        c.advance_parallel(&[w]);
        assert_eq!(c.data_hidden, 0.5);
        assert_eq!(c.data_exposed, 0.3);
        assert_eq!(c.seconds, 2.3);
        let mut d = ClusterClock::new();
        d.absorb(&c);
        assert_eq!(d.data_hidden, 0.5);
        assert_eq!(d.data_exposed, 0.3);
        assert_eq!(d.seconds, 2.3);
    }

    #[test]
    fn eval_not_in_training_time() {
        let mut c = ClusterClock::new();
        c.advance_compute(1.0);
        c.note_eval(10.0);
        assert_eq!(c.seconds, 1.0);
        assert_eq!(c.eval, 10.0);
    }

    #[test]
    fn dropped_worker_time_reported_outside_training_time() {
        let mut c = ClusterClock::new();
        c.advance_compute(1.0);
        c.note_drop(7.0);
        assert_eq!(c.seconds, 1.0);
        assert_eq!(c.lost, 7.0);
        // lost survives parallel merges and serial absorbs
        let mut outer = ClusterClock::new();
        outer.advance_parallel(&[c]);
        assert_eq!(outer.lost, 7.0);
        assert_eq!(outer.seconds, 1.0);
        let mut top = ClusterClock::new();
        top.absorb(&outer);
        assert_eq!(top.lost, 7.0);
    }

    #[test]
    fn absorb_sums_components() {
        let mut a = ClusterClock::new();
        a.advance_compute(1.0);
        let mut b = ClusterClock::new();
        b.advance_comm(2.0);
        b.note_eval(0.5);
        a.absorb(&b);
        assert_eq!(a.seconds, 3.0);
        assert_eq!(a.comm, 2.0);
        assert_eq!(a.eval, 0.5);
    }
}
