//! Discrete-event cluster clock with a compute/communication breakdown.
//!
//! Phase 1 advances by `compute + allreduce` per synchronous step; phase 2
//! advances by the max of the (identical) per-worker durations via
//! `advance_parallel`. Evaluation passes are tracked separately and do NOT
//! count toward training time (the paper's tables report training time).

#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct ClusterClock {
    /// modeled training seconds
    pub seconds: f64,
    /// breakdown: device compute
    pub compute: f64,
    /// breakdown: communication (all-reduce, broadcast)
    pub comm: f64,
    /// modeled evaluation seconds (reported, not part of `seconds`)
    pub eval: f64,
}

impl ClusterClock {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn advance_compute(&mut self, dt: f64) {
        debug_assert!(dt >= 0.0);
        self.seconds += dt;
        self.compute += dt;
    }

    pub fn advance_comm(&mut self, dt: f64) {
        debug_assert!(dt >= 0.0);
        self.seconds += dt;
        self.comm += dt;
    }

    /// Advance by the slowest of parallel worker durations (phase 2: the
    /// cluster waits for all independent workers to finish).
    pub fn advance_parallel(&mut self, worker_durations: &[f64]) {
        let max = worker_durations.iter().cloned().fold(0.0, f64::max);
        self.advance_compute(max);
    }

    pub fn note_eval(&mut self, dt: f64) {
        self.eval += dt;
    }

    /// Merge a sub-phase clock (e.g. a worker's own clock) serially.
    pub fn absorb(&mut self, other: &ClusterClock) {
        self.seconds += other.seconds;
        self.compute += other.compute;
        self.comm += other.comm;
        self.eval += other.eval;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn advances_accumulate() {
        let mut c = ClusterClock::new();
        c.advance_compute(1.0);
        c.advance_comm(0.5);
        assert_eq!(c.seconds, 1.5);
        assert_eq!(c.compute, 1.0);
        assert_eq!(c.comm, 0.5);
    }

    #[test]
    fn parallel_takes_max() {
        let mut c = ClusterClock::new();
        c.advance_parallel(&[1.0, 3.0, 2.0]);
        assert_eq!(c.seconds, 3.0);
        c.advance_parallel(&[]);
        assert_eq!(c.seconds, 3.0);
    }

    #[test]
    fn eval_not_in_training_time() {
        let mut c = ClusterClock::new();
        c.advance_compute(1.0);
        c.note_eval(10.0);
        assert_eq!(c.seconds, 1.0);
        assert_eq!(c.eval, 10.0);
    }

    #[test]
    fn absorb_sums_components() {
        let mut a = ClusterClock::new();
        a.advance_compute(1.0);
        let mut b = ClusterClock::new();
        b.advance_comm(2.0);
        b.note_eval(0.5);
        a.absorb(&b);
        assert_eq!(a.seconds, 3.0);
        assert_eq!(a.comm, 2.0);
        assert_eq!(a.eval, 0.5);
    }
}
