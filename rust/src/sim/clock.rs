//! Discrete-event cluster clock with a compute/communication breakdown.
//!
//! Phase 1 advances by `compute + allreduce` per synchronous step; phase 2
//! advances by the slowest per-worker clock via `advance_parallel`, which
//! absorbs that worker's own compute/comm breakdown. Evaluation passes are
//! tracked separately and do NOT count toward training time (the paper's
//! tables report training time).

#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct ClusterClock {
    /// modeled training seconds
    pub seconds: f64,
    /// breakdown: device compute
    pub compute: f64,
    /// breakdown: communication (all-reduce, broadcast)
    pub comm: f64,
    /// modeled evaluation seconds (reported, not part of `seconds`)
    pub eval: f64,
}

impl ClusterClock {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn advance_compute(&mut self, dt: f64) {
        debug_assert!(dt >= 0.0);
        self.seconds += dt;
        self.compute += dt;
    }

    pub fn advance_comm(&mut self, dt: f64) {
        debug_assert!(dt >= 0.0);
        self.seconds += dt;
        self.comm += dt;
    }

    /// Advance by the slowest of parallel worker clocks (phase 2: the
    /// cluster waits for all independent workers to finish). The slowest
    /// worker's own compute/comm breakdown is absorbed — booking its total
    /// as pure compute would lose the communication component whenever a
    /// phase-2 group is itself data-parallel (`group_devices > 1`).
    /// Evaluation seconds are summed over all workers (eval is reported,
    /// never part of training `seconds`).
    pub fn advance_parallel(&mut self, workers: &[ClusterClock]) {
        if let Some(slowest) = workers
            .iter()
            .max_by(|a, b| a.seconds.total_cmp(&b.seconds))
        {
            debug_assert!(slowest.seconds >= 0.0);
            self.seconds += slowest.seconds;
            self.compute += slowest.compute;
            self.comm += slowest.comm;
        }
        for w in workers {
            self.eval += w.eval;
        }
    }

    pub fn note_eval(&mut self, dt: f64) {
        self.eval += dt;
    }

    /// Merge a sub-phase clock (e.g. a worker's own clock) serially.
    pub fn absorb(&mut self, other: &ClusterClock) {
        self.seconds += other.seconds;
        self.compute += other.compute;
        self.comm += other.comm;
        self.eval += other.eval;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn advances_accumulate() {
        let mut c = ClusterClock::new();
        c.advance_compute(1.0);
        c.advance_comm(0.5);
        assert_eq!(c.seconds, 1.5);
        assert_eq!(c.compute, 1.0);
        assert_eq!(c.comm, 0.5);
    }

    #[test]
    fn parallel_takes_max() {
        let worker = |compute: f64, comm: f64| {
            let mut w = ClusterClock::new();
            w.advance_compute(compute);
            w.advance_comm(comm);
            w
        };
        let mut c = ClusterClock::new();
        c.advance_parallel(&[worker(1.0, 0.0), worker(2.0, 1.0), worker(2.0, 0.0)]);
        assert_eq!(c.seconds, 3.0);
        c.advance_parallel(&[]);
        assert_eq!(c.seconds, 3.0);
    }

    #[test]
    fn parallel_keeps_comm_breakdown() {
        // the slowest worker's compute/comm split must survive (a phase-2
        // group with group_devices > 1 pays all-reduce time every step)
        let mut slow = ClusterClock::new();
        slow.advance_compute(4.0);
        slow.advance_comm(2.0);
        let mut fast = ClusterClock::new();
        fast.advance_compute(1.0);
        fast.note_eval(0.5);
        let mut c = ClusterClock::new();
        c.advance_compute(10.0); // phase 1
        c.advance_parallel(&[fast, slow]);
        assert_eq!(c.seconds, 16.0);
        assert_eq!(c.compute, 14.0);
        assert_eq!(c.comm, 2.0);
        // eval sums over all workers, outside training time
        assert_eq!(c.eval, 0.5);
    }

    #[test]
    fn eval_not_in_training_time() {
        let mut c = ClusterClock::new();
        c.advance_compute(1.0);
        c.note_eval(10.0);
        assert_eq!(c.seconds, 1.0);
        assert_eq!(c.eval, 10.0);
    }

    #[test]
    fn absorb_sums_components() {
        let mut a = ClusterClock::new();
        a.advance_compute(1.0);
        let mut b = ClusterClock::new();
        b.advance_comm(2.0);
        b.note_eval(0.5);
        a.absorb(&b);
        assert_eq!(a.seconds, 3.0);
        assert_eq!(a.comm, 2.0);
        assert_eq!(a.eval, 0.5);
    }
}
