//! Mini property-based testing framework (the vendored crate set has no
//! proptest/quickcheck, so we build the substrate ourselves).
//!
//! Usage:
//! ```no_run
//! use swap::testutil::{property, Gen};
//! property(100, |g| {
//!     let xs = g.vec_f32(1..200, -10.0..10.0);
//!     let sum: f32 = xs.iter().sum();
//!     // associativity-ish sanity
//!     assert!((sum - xs.iter().rev().sum::<f32>()).abs() < 1e-3);
//! });
//! ```
//!
//! On failure the runner re-raises the panic together with the seed of the
//! failing case; re-running with `SWAP_PROP_SEED=<seed>` reproduces exactly
//! one case. Shrinking is "lite": integer and vector-length generators bias
//! a fraction of their draws toward minimal values so small counterexamples
//! are likely in the first place.

use std::ops::Range;
use std::panic::{catch_unwind, AssertUnwindSafe};

use crate::util::Rng;

/// Value generator handed to each property case.
pub struct Gen {
    rng: Rng,
    /// case index (0-based); case 0..SMALL_CASES bias toward minimal values
    case: usize,
}

const SMALL_CASES: usize = 8;

impl Gen {
    fn new(seed: u64, case: usize) -> Self {
        Gen { rng: Rng::stream(seed, case as u64), case }
    }

    /// Uniform usize in range; early cases bias to the low end (shrink-lite).
    pub fn usize_in(&mut self, r: Range<usize>) -> usize {
        assert!(r.start < r.end, "empty range");
        if self.case < SMALL_CASES {
            let span = (r.end - r.start).min(self.case + 1);
            r.start + self.rng.below(span)
        } else {
            r.start + self.rng.below(r.end - r.start)
        }
    }

    pub fn i64_in(&mut self, r: Range<i64>) -> i64 {
        assert!(r.start < r.end);
        let span = (r.end - r.start) as u64;
        let off = if self.case < SMALL_CASES {
            self.rng.below(span.min(self.case as u64 + 1) as usize) as u64
        } else {
            self.rng.next_u64() % span
        };
        r.start + off as i64
    }

    pub fn f32_in(&mut self, r: Range<f32>) -> f32 {
        r.start + self.rng.next_f32() * (r.end - r.start)
    }

    pub fn f64_in(&mut self, r: Range<f64>) -> f64 {
        r.start + self.rng.next_f64() * (r.end - r.start)
    }

    pub fn bool(&mut self) -> bool {
        self.rng.coin(0.5)
    }

    pub fn normal(&mut self) -> f32 {
        self.rng.normal()
    }

    pub fn vec_f32(&mut self, len: Range<usize>, vals: Range<f32>) -> Vec<f32> {
        let n = self.usize_in(len);
        (0..n).map(|_| self.f32_in(vals.clone())).collect()
    }

    pub fn vec_normal(&mut self, len: Range<usize>) -> Vec<f32> {
        let n = self.usize_in(len);
        (0..n).map(|_| self.rng.normal()).collect()
    }

    pub fn choose<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        &xs[self.rng.below(xs.len())]
    }

    /// Raw RNG access for anything custom.
    pub fn rng(&mut self) -> &mut Rng {
        &mut self.rng
    }
}

/// Run `f` over `cases` generated inputs. Panics (with seed info) on the
/// first failing case.
pub fn property(cases: usize, f: impl Fn(&mut Gen)) {
    let seed: u64 = std::env::var("SWAP_PROP_SEED")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(0xC0FFEE);
    let only_case: Option<usize> = std::env::var("SWAP_PROP_CASE")
        .ok()
        .and_then(|s| s.parse().ok());

    for case in 0..cases {
        if let Some(oc) = only_case {
            if case != oc {
                continue;
            }
        }
        let mut g = Gen::new(seed, case);
        let result = catch_unwind(AssertUnwindSafe(|| f(&mut g)));
        if let Err(panic) = result {
            let msg = panic
                .downcast_ref::<String>()
                .cloned()
                .or_else(|| panic.downcast_ref::<&str>().map(|s| s.to_string()))
                .unwrap_or_else(|| "<non-string panic>".into());
            panic!(
                "property failed at case {case} (reproduce with \
                 SWAP_PROP_SEED={seed} SWAP_PROP_CASE={case}): {msg}"
            );
        }
    }
}

/// assert_close for floats with a readable message.
pub fn assert_close(a: f64, b: f64, tol: f64, what: &str) {
    let scale = 1.0f64.max(a.abs()).max(b.abs());
    assert!(
        (a - b).abs() <= tol * scale,
        "{what}: {a} vs {b} (tol {tol}, scale {scale})"
    );
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn property_runs_all_cases() {
        let mut count = 0usize;
        let counter = std::sync::atomic::AtomicUsize::new(0);
        property(50, |_g| {
            counter.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
        });
        count += counter.load(std::sync::atomic::Ordering::Relaxed);
        assert_eq!(count, 50);
    }

    #[test]
    fn early_cases_are_small() {
        property(SMALL_CASES, |g| {
            let n = g.usize_in(1..1000);
            assert!(n <= SMALL_CASES, "case should be small, got {n}");
        });
    }

    #[test]
    #[should_panic(expected = "SWAP_PROP_SEED")]
    fn failure_reports_seed() {
        property(10, |g| {
            let n = g.usize_in(1..100);
            assert!(n < 10_000); // passes
            if g.bool() || true {
                panic!("boom");
            }
        });
    }

    #[test]
    fn generators_in_range() {
        property(200, |g| {
            let u = g.usize_in(3..17);
            assert!((3..17).contains(&u));
            let i = g.i64_in(-5..6);
            assert!((-5..6).contains(&i));
            let f = g.f32_in(-1.0..1.0);
            assert!((-1.0..1.0).contains(&f));
            let v = g.vec_f32(0..9, 0.0..1.0);
            assert!(v.len() < 9);
        });
    }

    #[test]
    fn assert_close_scales() {
        assert_close(1000.0, 1000.1, 1e-3, "big");
        assert_close(0.0, 1e-9, 1e-6, "small");
    }

    #[test]
    #[should_panic]
    fn assert_close_fails_when_far() {
        assert_close(1.0, 2.0, 1e-3, "far");
    }
}
