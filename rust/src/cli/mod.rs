//! CLI argument parsing (no clap in the vendored crate set) and the
//! subcommand surface of the `swap-train` binary.
//!
//! ```text
//! swap-train <command> [--preset NAME] [--config FILE] [--set key=value]...
//!            [--runs N] [--seed N] [--threads N] [--simd TIER]
//! ```
//!
//! Commands: swap | serve | join | swap-resume | serve-model | sb | lb |
//!           swa | local-sgd | table1 | table2 | table3 | table4 |
//!           dawnbench | fig1 | fig2 | fig3 | fig4 | fig5 | fig6 |
//!           schedules | info | help

use crate::config::{preset, ExperimentConfig};
use crate::util::{Error, Result};

/// Parsed command line.
#[derive(Debug, Clone, PartialEq)]
pub struct Args {
    pub command: String,
    /// --key value / --key=value flags (key without the dashes)
    pub flags: Vec<(String, String)>,
    /// bare --flags (no value)
    pub switches: Vec<String>,
}

const VALUE_FLAGS: &[&str] = &[
    "preset", "config", "set", "runs", "seed", "threads", "simd", "out", "addr", "worker", "model",
];

impl Args {
    pub fn parse(argv: &[String]) -> Result<Args> {
        let mut it = argv.iter().peekable();
        let command = it
            .next()
            .cloned()
            .unwrap_or_else(|| "help".to_string());
        if command.starts_with('-') {
            return Err(Error::config(format!(
                "expected a command first, got flag '{command}'"
            )));
        }
        let mut flags = Vec::new();
        let mut switches = Vec::new();
        while let Some(arg) = it.next() {
            let Some(stripped) = arg.strip_prefix("--") else {
                return Err(Error::config(format!("unexpected argument '{arg}'")));
            };
            if let Some((k, v)) = stripped.split_once('=') {
                flags.push((k.to_string(), v.to_string()));
            } else if VALUE_FLAGS.contains(&stripped) {
                let v = it
                    .next()
                    .ok_or_else(|| Error::config(format!("flag --{stripped} needs a value")))?;
                flags.push((stripped.to_string(), v.clone()));
            } else {
                switches.push(stripped.to_string());
            }
        }
        Ok(Args { command, flags, switches })
    }

    pub fn get(&self, key: &str) -> Option<&str> {
        self.flags
            .iter()
            .rev()
            .find(|(k, _)| k == key)
            .map(|(_, v)| v.as_str())
    }

    pub fn get_all(&self, key: &str) -> Vec<&str> {
        self.flags
            .iter()
            .filter(|(k, _)| k == key)
            .map(|(_, v)| v.as_str())
            .collect()
    }

    pub fn has(&self, switch: &str) -> bool {
        self.switches.iter().any(|s| s == switch)
    }

    /// Build the experiment config: preset (or the command's default) +
    /// --config file + --set overrides + --runs/--seed shorthands.
    pub fn config(&self, default_preset: &str) -> Result<ExperimentConfig> {
        let name = self.get("preset").unwrap_or(default_preset);
        let mut cfg = preset(name)?;
        if let Some(path) = self.get("config") {
            cfg.apply_file(path)?;
        }
        for kv in self.get_all("set") {
            let (k, v) = kv
                .split_once('=')
                .ok_or_else(|| Error::config(format!("--set wants key=value, got '{kv}'")))?;
            cfg.apply_kv(k, v)?;
        }
        if let Some(r) = self.get("runs") {
            cfg.apply_kv("runs", r)?;
        }
        if let Some(s) = self.get("seed") {
            cfg.apply_kv("seed", s)?;
        }
        if let Some(t) = self.get("threads") {
            cfg.apply_kv("threads", t)?;
        }
        if let Some(s) = self.get("simd") {
            cfg.apply_kv("simd", s)?;
        }
        cfg.validate()?;
        Ok(cfg)
    }
}

/// The default dataset preset for each subcommand.
pub fn default_preset_for(command: &str) -> &'static str {
    match command {
        "table2" | "table4" => "cifar100sim",
        "table3" => "imagenetsim",
        _ => "cifar10sim",
    }
}

pub const HELP: &str = "\
swap-train — SWAP (Stochastic Weight Averaging in Parallel, ICLR 2020)

USAGE:  swap-train <command> [--preset NAME] [--config FILE] [--set key=value]...
                   [--runs N] [--seed N] [--threads N] [--simd TIER]

Training commands (print a run summary):
  swap        run the three-phase SWAP algorithm (phase 2 in-process)
  swap-resume restartable SWAP: phase checkpoints under --out DIR
  serve       coordinator: phase 1 locally (or as the hub of a
              distributed collective with --set phase1_dist=true), then
              serve phase 2 to remote `join` processes on --addr (TCP
              host:port or a unix socket path); workers that crash,
              hang, or straggle are dropped under the failure policy —
              phase 1 re-forms the collective from survivors, phase 2
              drops them from the average; state persists under --out
              so a re-serve resumes phase 1 from the last recorded sync
              step and retries only the dropped phase-2 workers
  join        worker: connect to a `serve` coordinator at --addr; when
              phase1_dist=true, first computes phase-1 gradient shards
              for the collective, then trains one phase-2 replica and
              uploads it (--worker N requests a specific member slot /
              unfinished worker id when rejoining)
  serve-model batched inference serving on an averaged-model checkpoint
              (--model FILE, saved by `swap --out DIR` as DIR/model.ckpt);
              coalesces requests through the dynamic batcher across
              serve_threads shard engines and reports accuracy, p50/p99
              latency and throughput over the test set
  sb          small-batch SGD baseline
  lb          large-batch SGD baseline
  swa         sequential SWA from a small-batch run
  local-sgd   post-local SGD extension

Paper reproduction (write results/*.txt + *.csv):
  table1      CIFAR10(sim)  SB vs LB vs SWAP          [preset cifar10sim]
  table2      CIFAR100(sim) SB vs LB vs SWAP          [preset cifar100sim]
  table3      ImageNet(sim) Top1/Top5 SB vs LB vs SWAP [preset imagenetsim]
  table4      SWA vs SWAP                             [preset cifar100sim]
  dawnbench   time-to-target accuracy (§5.1)
  fig1        LR schedule + per-worker accuracy curves
  fig2 fig3   loss-landscape planes (runs both)
  fig4        cosine(−g, θ_swap − θ) series
  schedules   fig5 + fig6 LR/batch schedule series
  info        print preset config + artifact manifest

Presets: tiny | native | cifar10sim | cifar100sim | imagenetsim
Backends (--set backend=...):
  native    pure-rust engine, no artifacts needed        [default]
  xla       PJRT over AOT HLO artifacts (build with --features xla,
            generate artifacts with `python -m compile.aot`)
Data (--set data=... [--set data_dir=DIR]):
  synth     generated dataset (hermetic)                 [default]
  cifar10   on-disk CIFAR-10 binaries (data_batch_*.bin in data_dir)
  cifar100  on-disk CIFAR-100 binaries (train.bin/test.bin in data_dir)
Prefetch (--set prefetch=true|false):
  true      assemble step t+1 on a background thread while the backend
            computes step t (bitwise identical either way)     [default]
Threads (--threads N / --set threads=N):
  0         auto: SWAP_THREADS env var, else available parallelism [default]
  1         fully sequential execution
  N         phase-2 workers / phase-1 shards / native kernels on N OS
            threads; results are bitwise identical for every N
SIMD (--simd TIER / --set simd=TIER):
  auto      runtime feature detection (avx2 on x86_64, neon on
            aarch64, else scalar)                            [default]
  scalar    portable kernels — the parity oracle every tier must match
  avx2|neon force a vector tier; an unavailable tier is a config error;
            all tiers are bitwise identical (SWAP_SIMD env overrides)
Averaging (--set averaging=..., applies to SWAP phase 3, swa, local-sgd):
  uniform       plain mean over candidates (bitwise the historical
                behaviour)                                       [default]
  swa           incremental running average (Izmailov et al. recurrence)
  hierarchical  within-group running means, then across-group mean
                (avg_groups=N round-robin groups)                [groups 2]
  adaptive      start averaging once validation accuracy stops improving
                by avg_min_improve, keep the last avg_window candidates
                (needs val_examples>0; synth mints a disjoint split,
                disk sources carve the train tail)    [window 4, improve 0]
Serving (serve-model, all settable via --set):
  serve_threads=N        shard engine workers, each owning a private
                         workspace (0 = auto like threads)          [0]
  serve_max_batch=N      largest coalesced batch                    [8]
  serve_max_delay_us=N   batching window past the first request  [2000]
  serve_quant=f32|int8   numeric tier; int8 quantizes conv/linear
                         weights per-tensor at load and runs i8 GEMMs
                         (top-1/logit tolerance parity vs f32)    [f32]
  serve_queue_depth=N    pending-request ring capacity before the
                         server sheds load with an overload error
                         (0 = auto: shards x serve_max_batch x 2)  [0]
Distributed phase 1 (serve/join, all settable via --set):
  phase1_dist=BOOL       serve phase 1 as a socket collective: joins
                         compute gradient shards, the hub averages and
                         steps; bitwise identical to in-process [false]
  phase1_record_every=N  fsync the phase-1 progress record every N
                         sync steps (crash-safe resume granularity) [1]
Failure policy (serve/join, all settable via --set):
  min_workers=N          fewest survivors: phase-1 collective members
                         and phase-2 replicas to average         [1]
  connect_timeout_ms=N   serve: join window per phase            [60000]
  io_timeout_ms=N        drop a worker silent this long          [10000]
  heartbeat_ms=N         worker heartbeat interval               [1000]
  straggler_ms=N         grace after the first finished worker   [600000]
  join_retries=N         client connect attempts                 [60]
  retry_backoff_ms=N     linear backoff ramp between attempts,
                         jittered per-process to break stampedes [500]
Env: SWAP_RUNS=N override runs, SWAP_THREADS=N default thread count,
     SWAP_PREFETCH=0|1 override prefetch, SWAP_SIMD=auto|scalar|avx2|neon
     override simd tier, SWAP_LOG=debug|info|warn|quiet";

#[cfg(test)]
mod tests {
    use super::*;

    fn argv(s: &[&str]) -> Vec<String> {
        s.iter().map(|x| x.to_string()).collect()
    }

    #[test]
    fn parses_command_and_flags() {
        let a = Args::parse(&argv(&["swap", "--preset", "tiny", "--set", "runs=2"])).unwrap();
        assert_eq!(a.command, "swap");
        assert_eq!(a.get("preset"), Some("tiny"));
        assert_eq!(a.get_all("set"), vec!["runs=2"]);
    }

    #[test]
    fn parses_equals_form_and_switches() {
        let a = Args::parse(&argv(&["fig1", "--preset=tiny", "--quiet"])).unwrap();
        assert_eq!(a.get("preset"), Some("tiny"));
        assert!(a.has("quiet"));
        assert!(!a.has("loud"));
    }

    #[test]
    fn last_flag_wins() {
        let a = Args::parse(&argv(&["swap", "--seed", "1", "--seed", "2"])).unwrap();
        assert_eq!(a.get("seed"), Some("2"));
    }

    #[test]
    fn rejects_malformed() {
        assert!(Args::parse(&argv(&["--preset", "x"])).is_err());
        assert!(Args::parse(&argv(&["swap", "stray"])).is_err());
        assert!(Args::parse(&argv(&["swap", "--preset"])).is_err());
    }

    #[test]
    fn empty_is_help() {
        assert_eq!(Args::parse(&[]).unwrap().command, "help");
    }

    #[test]
    fn config_applies_overrides() {
        let a = Args::parse(&argv(&[
            "swap",
            "--preset",
            "tiny",
            "--set",
            "n_train=128",
            "--runs",
            "9",
            "--seed",
            "77",
            "--threads",
            "2",
        ]))
        .unwrap();
        let cfg = a.config("cifar10sim").unwrap();
        assert_eq!(cfg.preset, "tiny");
        assert_eq!(cfg.n_train, 128);
        assert_eq!(cfg.runs, 9);
        assert_eq!(cfg.seed, 77);
        assert_eq!(cfg.threads, 2);
    }

    #[test]
    fn simd_flag_sets_knob_and_validates() {
        let a = Args::parse(&argv(&["swap", "--preset", "tiny", "--simd", "scalar"])).unwrap();
        assert_eq!(a.get("simd"), Some("scalar"));
        let cfg = a.config("tiny").unwrap();
        assert_eq!(cfg.simd, "scalar");
        // an unknown tier is rejected at validation (unless the SWAP_SIMD
        // env override is set — then the knob is ignored entirely)
        if std::env::var("SWAP_SIMD").is_err() {
            let a = Args::parse(&argv(&["swap", "--preset", "tiny", "--simd", "sse9"])).unwrap();
            assert!(a.config("tiny").is_err());
        }
    }

    #[test]
    fn config_rejects_bad_set() {
        let a = Args::parse(&argv(&["swap", "--preset", "tiny", "--set", "oops"])).unwrap();
        assert!(a.config("tiny").is_err());
        let a = Args::parse(&argv(&["swap", "--preset", "tiny", "--set", "zzz=1"])).unwrap();
        assert!(a.config("tiny").is_err());
    }

    #[test]
    fn serve_join_flags_take_values() {
        let a = Args::parse(&argv(&[
            "join", "--addr", "127.0.0.1:9000", "--worker", "3", "--preset", "tiny",
        ]))
        .unwrap();
        assert_eq!(a.command, "join");
        assert_eq!(a.get("addr"), Some("127.0.0.1:9000"));
        assert_eq!(a.get("worker"), Some("3"));
        let a = Args::parse(&argv(&["serve", "--addr=/tmp/swap.sock"])).unwrap();
        assert_eq!(a.get("addr"), Some("/tmp/swap.sock"));
    }

    #[test]
    fn default_presets() {
        assert_eq!(default_preset_for("table2"), "cifar100sim");
        assert_eq!(default_preset_for("table3"), "imagenetsim");
        assert_eq!(default_preset_for("table1"), "cifar10sim");
    }
}
