//! Bench harness (the vendored crate set has no criterion, so we build the
//! substrate ourselves). Drives the `rust/benches/*` binaries
//! (`harness = false`): warmup, repeated timed runs, mean/std/min, and a
//! simple table/CSV reporter shared by every paper-table bench.

use std::time::Instant;

/// Timing statistics over repeated runs.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Stats {
    pub mean: f64,
    pub std: f64,
    pub min: f64,
    pub max: f64,
    pub n: usize,
}

/// mean ± std over a sample (population std; the paper reports ±std).
pub fn stats(xs: &[f64]) -> Stats {
    assert!(!xs.is_empty(), "stats of empty sample");
    let n = xs.len() as f64;
    let mean = xs.iter().sum::<f64>() / n;
    let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n;
    Stats {
        mean,
        std: var.sqrt(),
        min: xs.iter().cloned().fold(f64::INFINITY, f64::min),
        max: xs.iter().cloned().fold(f64::NEG_INFINITY, f64::max),
        n: xs.len(),
    }
}

/// Time one closure invocation in seconds.
pub fn time_once<T>(f: impl FnOnce() -> T) -> (f64, T) {
    let t0 = Instant::now();
    let out = f();
    (t0.elapsed().as_secs_f64(), out)
}

/// Micro-benchmark: `warmup` unmeasured runs then `runs` measured ones.
pub fn bench(warmup: usize, runs: usize, mut f: impl FnMut()) -> Stats {
    for _ in 0..warmup {
        f();
    }
    let samples: Vec<f64> = (0..runs)
        .map(|_| {
            let t0 = Instant::now();
            f();
            t0.elapsed().as_secs_f64()
        })
        .collect();
    stats(&samples)
}

/// Fixed-width table printer used by every paper-table bench so outputs are
/// visually comparable with the paper's rows.
pub struct Table {
    title: String,
    headers: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(title: &str, headers: &[&str]) -> Self {
        Table {
            title: title.to_string(),
            headers: headers.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row(&mut self, cells: &[String]) {
        assert_eq!(cells.len(), self.headers.len(), "ragged table row");
        self.rows.push(cells.to_vec());
    }

    pub fn render(&self) -> String {
        let mut widths: Vec<usize> = self.headers.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (w, c) in widths.iter_mut().zip(row) {
                *w = (*w).max(c.len());
            }
        }
        let mut out = String::new();
        out.push_str(&format!("== {} ==\n", self.title));
        let fmt_row = |cells: &[String], widths: &[usize]| -> String {
            let mut line = String::from("| ");
            for (c, w) in cells.iter().zip(widths) {
                line.push_str(&format!("{c:<w$} | ", w = w));
            }
            line.push('\n');
            line
        };
        out.push_str(&fmt_row(&self.headers, &widths));
        let total: usize = widths.iter().map(|w| w + 3).sum::<usize>() + 1;
        out.push_str(&"-".repeat(total));
        out.push('\n');
        for row in &self.rows {
            out.push_str(&fmt_row(row, &widths));
        }
        out
    }

    pub fn print(&self) {
        println!("{}", self.render());
    }

    /// CSV form, written next to the printed table for EXPERIMENTS.md.
    pub fn to_csv(&self) -> String {
        let esc = |s: &str| {
            if s.contains(',') || s.contains('"') {
                format!("\"{}\"", s.replace('"', "\"\""))
            } else {
                s.to_string()
            }
        };
        let mut out = self.headers.iter().map(|h| esc(h)).collect::<Vec<_>>().join(",");
        out.push('\n');
        for row in &self.rows {
            out.push_str(&row.iter().map(|c| esc(c)).collect::<Vec<_>>().join(","));
            out.push('\n');
        }
        out
    }
}

/// "mean ± std" with sensible precision, matching the paper's table style.
pub fn pm(mean: f64, std: f64) -> String {
    if mean.abs() >= 100.0 {
        format!("{mean:.2} ± {std:.2}")
    } else {
        format!("{mean:.3} ± {std:.3}")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stats_mean_std() {
        let s = stats(&[1.0, 2.0, 3.0, 4.0]);
        assert_eq!(s.mean, 2.5);
        assert!((s.std - (1.25f64).sqrt()).abs() < 1e-12);
        assert_eq!(s.min, 1.0);
        assert_eq!(s.max, 4.0);
        assert_eq!(s.n, 4);
    }

    #[test]
    fn bench_counts_runs() {
        let count = std::cell::Cell::new(0);
        let s = bench(2, 5, || {
            count.set(count.get() + 1);
        });
        assert_eq!(count.get(), 7);
        assert_eq!(s.n, 5);
        assert!(s.mean >= 0.0);
    }

    #[test]
    fn table_renders_and_csvs() {
        let mut t = Table::new("T", &["a", "bb"]);
        t.row(&["1".into(), "x,y".into()]);
        let r = t.render();
        assert!(r.contains("== T ==") && r.contains("| 1 "));
        let csv = t.to_csv();
        assert!(csv.contains("\"x,y\""));
    }

    #[test]
    #[should_panic(expected = "ragged")]
    fn ragged_row_panics() {
        let mut t = Table::new("T", &["a"]);
        t.row(&["1".into(), "2".into()]);
    }

    #[test]
    fn pm_formats() {
        assert_eq!(pm(95.234, 0.087), "95.234 ± 0.087");
        assert_eq!(pm(254.12, 0.62), "254.12 ± 0.62");
    }
}
