//! Bench harness (the vendored crate set has no criterion, so we build the
//! substrate ourselves). Drives the `rust/benches/*` binaries
//! (`harness = false`): warmup, repeated timed runs, mean/std/min, and a
//! simple table/CSV reporter shared by every paper-table bench.

use std::time::Instant;

use crate::util::{simd, Json};

/// Environment manifest attached to the bench JSON artifacts
/// (BENCH_gemm.json, the dawnbench rows of BENCH_parallel.json) so the
/// perf trajectory is diffable across machines: target os/arch, the SIMD
/// tier the kernels actually dispatch on (and what detection alone would
/// pick), the rustc version and the CPU brand string. The latter two are
/// best-effort — null when the toolchain or /proc/cpuinfo is absent.
pub fn env_manifest() -> Json {
    let opt = |v: Option<String>| v.map(Json::str).unwrap_or(Json::Null);
    Json::obj(vec![
        ("os", Json::str(std::env::consts::OS)),
        ("arch", Json::str(std::env::consts::ARCH)),
        ("simd_tier", Json::str(simd::active().name())),
        ("simd_detected", Json::str(simd::detect().name())),
        ("rustc", opt(rustc_version())),
        ("cpu", opt(cpu_model())),
    ])
}

/// `rustc --version` of the toolchain on PATH, if any.
fn rustc_version() -> Option<String> {
    let out = std::process::Command::new("rustc").arg("--version").output().ok()?;
    if !out.status.success() {
        return None;
    }
    let v = String::from_utf8(out.stdout).ok()?;
    let v = v.trim();
    (!v.is_empty()).then(|| v.to_string())
}

/// CPU brand string from /proc/cpuinfo (linux; the CI and bench hosts).
fn cpu_model() -> Option<String> {
    let text = std::fs::read_to_string("/proc/cpuinfo").ok()?;
    for line in text.lines() {
        // x86 calls it "model name"; some arm kernels use "Processor"
        if let Some((k, v)) = line.split_once(':') {
            if matches!(k.trim(), "model name" | "Processor") && !v.trim().is_empty() {
                return Some(v.trim().to_string());
            }
        }
    }
    None
}

/// Timing statistics over repeated runs.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Stats {
    pub mean: f64,
    pub std: f64,
    pub min: f64,
    pub max: f64,
    pub n: usize,
}

/// mean ± std over a sample (population std; the paper reports ±std).
pub fn stats(xs: &[f64]) -> Stats {
    assert!(!xs.is_empty(), "stats of empty sample");
    let n = xs.len() as f64;
    let mean = xs.iter().sum::<f64>() / n;
    let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n;
    Stats {
        mean,
        std: var.sqrt(),
        min: xs.iter().cloned().fold(f64::INFINITY, f64::min),
        max: xs.iter().cloned().fold(f64::NEG_INFINITY, f64::max),
        n: xs.len(),
    }
}

/// Time one closure invocation in seconds.
pub fn time_once<T>(f: impl FnOnce() -> T) -> (f64, T) {
    let t0 = Instant::now();
    let out = f();
    (t0.elapsed().as_secs_f64(), out)
}

/// Micro-benchmark: `warmup` unmeasured runs then `runs` measured ones.
pub fn bench(warmup: usize, runs: usize, mut f: impl FnMut()) -> Stats {
    for _ in 0..warmup {
        f();
    }
    let samples: Vec<f64> = (0..runs)
        .map(|_| {
            let t0 = Instant::now();
            f();
            t0.elapsed().as_secs_f64()
        })
        .collect();
    stats(&samples)
}

/// Fixed-width table printer used by every paper-table bench so outputs are
/// visually comparable with the paper's rows.
pub struct Table {
    title: String,
    headers: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(title: &str, headers: &[&str]) -> Self {
        Table {
            title: title.to_string(),
            headers: headers.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row(&mut self, cells: &[String]) {
        assert_eq!(cells.len(), self.headers.len(), "ragged table row");
        self.rows.push(cells.to_vec());
    }

    pub fn render(&self) -> String {
        let mut widths: Vec<usize> = self.headers.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (w, c) in widths.iter_mut().zip(row) {
                *w = (*w).max(c.len());
            }
        }
        let mut out = String::new();
        out.push_str(&format!("== {} ==\n", self.title));
        let fmt_row = |cells: &[String], widths: &[usize]| -> String {
            let mut line = String::from("| ");
            for (c, w) in cells.iter().zip(widths) {
                line.push_str(&format!("{c:<w$} | ", w = w));
            }
            line.push('\n');
            line
        };
        out.push_str(&fmt_row(&self.headers, &widths));
        let total: usize = widths.iter().map(|w| w + 3).sum::<usize>() + 1;
        out.push_str(&"-".repeat(total));
        out.push('\n');
        for row in &self.rows {
            out.push_str(&fmt_row(row, &widths));
        }
        out
    }

    pub fn print(&self) {
        println!("{}", self.render());
    }

    /// CSV form, written next to the printed table for EXPERIMENTS.md.
    pub fn to_csv(&self) -> String {
        let esc = |s: &str| {
            if s.contains(',') || s.contains('"') {
                format!("\"{}\"", s.replace('"', "\"\""))
            } else {
                s.to_string()
            }
        };
        let mut out = self.headers.iter().map(|h| esc(h)).collect::<Vec<_>>().join(",");
        out.push('\n');
        for row in &self.rows {
            out.push_str(&row.iter().map(|c| esc(c)).collect::<Vec<_>>().join(","));
            out.push('\n');
        }
        out
    }
}

/// "mean ± std" with sensible precision, matching the paper's table style.
pub fn pm(mean: f64, std: f64) -> String {
    if mean.abs() >= 100.0 {
        format!("{mean:.2} ± {std:.2}")
    } else {
        format!("{mean:.3} ± {std:.3}")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stats_mean_std() {
        let s = stats(&[1.0, 2.0, 3.0, 4.0]);
        assert_eq!(s.mean, 2.5);
        assert!((s.std - (1.25f64).sqrt()).abs() < 1e-12);
        assert_eq!(s.min, 1.0);
        assert_eq!(s.max, 4.0);
        assert_eq!(s.n, 4);
    }

    #[test]
    fn bench_counts_runs() {
        let count = std::cell::Cell::new(0);
        let s = bench(2, 5, || {
            count.set(count.get() + 1);
        });
        assert_eq!(count.get(), 7);
        assert_eq!(s.n, 5);
        assert!(s.mean >= 0.0);
    }

    #[test]
    fn table_renders_and_csvs() {
        let mut t = Table::new("T", &["a", "bb"]);
        t.row(&["1".into(), "x,y".into()]);
        let r = t.render();
        assert!(r.contains("== T ==") && r.contains("| 1 "));
        let csv = t.to_csv();
        assert!(csv.contains("\"x,y\""));
    }

    #[test]
    #[should_panic(expected = "ragged")]
    fn ragged_row_panics() {
        let mut t = Table::new("T", &["a"]);
        t.row(&["1".into(), "2".into()]);
    }

    #[test]
    fn pm_formats() {
        assert_eq!(pm(95.234, 0.087), "95.234 ± 0.087");
        assert_eq!(pm(254.12, 0.62), "254.12 ± 0.62");
    }

    #[test]
    fn env_manifest_has_core_keys() {
        let m = env_manifest();
        assert_eq!(m.get("os").unwrap().as_str(), Some(std::env::consts::OS));
        assert_eq!(m.get("arch").unwrap().as_str(), Some(std::env::consts::ARCH));
        let tier = m.get("simd_tier").unwrap().as_str().unwrap();
        assert!(["scalar", "avx2", "neon"].contains(&tier));
        let detected = m.get("simd_detected").unwrap().as_str().unwrap();
        assert!(["scalar", "avx2", "neon"].contains(&detected));
        // rustc/cpu are best-effort: a string or null, never absent
        assert!(m.get("rustc").is_some());
        assert!(m.get("cpu").is_some());
        // the manifest round-trips through the serializer
        let text = m.to_string();
        assert!(Json::parse(&text).is_ok());
    }
}
