//! Multi-tensor operations over parameter *sets* (lists of tensors aligned
//! to the manifest order).
//!
//! Since the flat-arena refactor the hot paths run on contiguous arenas
//! via [`crate::tensor::flat`] and [`crate::model::flat`]; these per-tensor
//! versions are retained as the LEGACY REFERENCE implementations — the
//! bitwise oracles the parity tests (rust/tests/weightspace.rs) and the
//! old-vs-new `weightspace` bench compare against.

use super::Tensor;
use crate::util::{Error, Result};

/// Elementwise mean of several parameter sets: theta_hat = (1/W) sum theta_w.
/// This is the host-side twin of the L1 `weight_average` Pallas kernel
/// (integration tests cross-check the two).
pub fn average_sets(sets: &[Vec<Tensor>]) -> Result<Vec<Tensor>> {
    if sets.is_empty() {
        return Err(Error::invalid("average_sets: no sets"));
    }
    let w = sets.len() as f32;
    let mut out = sets[0].clone();
    for set in &sets[1..] {
        if set.len() != out.len() {
            return Err(Error::shape("average_sets: ragged sets"));
        }
        for (acc, t) in out.iter_mut().zip(set) {
            acc.axpy(1.0, t)?;
        }
    }
    for t in &mut out {
        t.scale(1.0 / w);
    }
    Ok(out)
}

/// sum over tensors of <a_i, b_i> — inner product on the full weight space.
pub fn sets_dot(a: &[Tensor], b: &[Tensor]) -> Result<f64> {
    if a.len() != b.len() {
        return Err(Error::shape("sets_dot: ragged sets"));
    }
    let mut acc = 0.0;
    for (x, y) in a.iter().zip(b) {
        acc += x.dot(y)?;
    }
    Ok(acc)
}

pub fn sets_sq_norm(a: &[Tensor]) -> f64 {
    a.iter().map(|t| t.sq_norm()).sum()
}

pub fn sets_norm(a: &[Tensor]) -> f64 {
    sets_sq_norm(a).sqrt()
}

/// Euclidean distance between two parameter sets.
pub fn sets_distance(a: &[Tensor], b: &[Tensor]) -> Result<f64> {
    if a.len() != b.len() {
        return Err(Error::shape("sets_distance: ragged sets"));
    }
    let mut acc = 0.0;
    for (x, y) in a.iter().zip(b) {
        if x.shape() != y.shape() {
            return Err(Error::shape("sets_distance: shape mismatch"));
        }
        acc += x
            .data()
            .iter()
            .zip(y.data())
            .map(|(p, q)| {
                let d = (*p - *q) as f64;
                d * d
            })
            .sum::<f64>();
    }
    Ok(acc.sqrt())
}

/// b - a as a new set (direction vectors for the landscape plane / Fig 4).
pub fn sets_sub(b: &[Tensor], a: &[Tensor]) -> Result<Vec<Tensor>> {
    if a.len() != b.len() {
        return Err(Error::shape("sets_sub: ragged sets"));
    }
    b.iter()
        .zip(a)
        .map(|(x, y)| {
            let mut d = x.clone();
            d.axpy(-1.0, y)?;
            Ok(d)
        })
        .collect()
}

/// out = base + alpha * dir (allocates; grid eval in the landscape).
pub fn sets_add_scaled(base: &[Tensor], alpha: f32, dir: &[Tensor]) -> Result<Vec<Tensor>> {
    if base.len() != dir.len() {
        return Err(Error::shape("sets_add_scaled: ragged sets"));
    }
    base.iter()
        .zip(dir)
        .map(|(b, d)| {
            let mut t = b.clone();
            t.axpy(alpha, d)?;
            Ok(t)
        })
        .collect()
}

/// In-place: acc += alpha * dir.
pub fn sets_axpy(acc: &mut [Tensor], alpha: f32, dir: &[Tensor]) -> Result<()> {
    if acc.len() != dir.len() {
        return Err(Error::shape("sets_axpy: ragged sets"));
    }
    for (a, d) in acc.iter_mut().zip(dir) {
        a.axpy(alpha, d)?;
    }
    Ok(())
}

/// In-place scale of a whole set.
pub fn sets_scale(acc: &mut [Tensor], alpha: f32) {
    for a in acc.iter_mut() {
        a.scale(alpha);
    }
}

/// Cosine similarity between two directions in weight space (Fig 4).
/// Returns 0 for degenerate (zero) vectors.
pub fn sets_cosine(a: &[Tensor], b: &[Tensor]) -> Result<f64> {
    let na = sets_norm(a);
    let nb = sets_norm(b);
    if na == 0.0 || nb == 0.0 {
        return Ok(0.0);
    }
    Ok(sets_dot(a, b)? / (na * nb))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn set(vals: &[&[f32]]) -> Vec<Tensor> {
        vals.iter()
            .map(|v| Tensor::new(vec![v.len()], v.to_vec()).unwrap())
            .collect()
    }

    #[test]
    fn average_of_identical_is_identity() {
        let s = set(&[&[1.0, 2.0], &[3.0]]);
        let avg = average_sets(&[s.clone(), s.clone(), s.clone()]).unwrap();
        assert_eq!(avg, s);
    }

    #[test]
    fn average_two_sets() {
        let a = set(&[&[0.0, 2.0]]);
        let b = set(&[&[4.0, 0.0]]);
        let avg = average_sets(&[a, b]).unwrap();
        assert_eq!(avg[0].data(), &[2.0, 1.0]);
    }

    #[test]
    fn average_empty_errors() {
        assert!(average_sets(&[]).is_err());
    }

    #[test]
    fn average_inside_convex_hull() {
        // mean is within [min,max] elementwise — phase-3 geometry invariant
        let sets: Vec<Vec<Tensor>> = (0..5)
            .map(|i| set(&[&[i as f32, -(i as f32) * 2.0, 1.0]]))
            .collect();
        let avg = average_sets(&sets).unwrap();
        for (j, &v) in avg[0].data().iter().enumerate() {
            let col: Vec<f32> = sets.iter().map(|s| s[0].data()[j]).collect();
            let mn = col.iter().cloned().fold(f32::INFINITY, f32::min);
            let mx = col.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
            assert!(v >= mn - 1e-6 && v <= mx + 1e-6);
        }
    }

    #[test]
    fn distance_and_dot() {
        let a = set(&[&[0.0, 0.0]]);
        let b = set(&[&[3.0, 4.0]]);
        assert_eq!(sets_distance(&a, &b).unwrap(), 5.0);
        assert_eq!(sets_dot(&b, &b).unwrap(), 25.0);
        assert_eq!(sets_norm(&b), 5.0);
    }

    #[test]
    fn sub_add_roundtrip() {
        let a = set(&[&[1.0, 2.0], &[3.0]]);
        let b = set(&[&[0.0, 5.0], &[-1.0]]);
        let d = sets_sub(&b, &a).unwrap();
        let b2 = sets_add_scaled(&a, 1.0, &d).unwrap();
        assert_eq!(b2, b);
    }

    #[test]
    fn cosine_bounds_and_orthogonality() {
        let a = set(&[&[1.0, 0.0]]);
        let b = set(&[&[0.0, 1.0]]);
        assert_eq!(sets_cosine(&a, &b).unwrap(), 0.0);
        assert!((sets_cosine(&a, &a).unwrap() - 1.0).abs() < 1e-12);
        let zero = set(&[&[0.0, 0.0]]);
        assert_eq!(sets_cosine(&a, &zero).unwrap(), 0.0);
    }

    #[test]
    fn axpy_scale_in_place() {
        let mut a = set(&[&[1.0, 1.0]]);
        let d = set(&[&[1.0, -1.0]]);
        sets_axpy(&mut a, 2.0, &d).unwrap();
        assert_eq!(a[0].data(), &[3.0, -1.0]);
        sets_scale(&mut a, 0.5);
        assert_eq!(a[0].data(), &[1.5, -0.5]);
    }

    #[test]
    fn ragged_sets_error() {
        let a = set(&[&[1.0]]);
        let b = set(&[&[1.0], &[2.0]]);
        assert!(sets_dot(&a, &b).is_err());
        assert!(sets_sub(&a, &b).is_err());
        assert!(average_sets(&[a, b]).is_err());
    }
}
