//! Host tensors: flat f32 storage + shape, and the vector math the
//! coordinator needs (optimizer updates, weight averaging, landscape
//! geometry). Kept free of any XLA types so it unit-tests instantly;
//! literal conversion lives in `runtime::literal`.

pub mod flat;
pub mod ops;

pub use ops::*;

use crate::util::{Error, Result};

/// A dense row-major f32 tensor on the host.
#[derive(Debug, Clone, PartialEq)]
pub struct Tensor {
    shape: Vec<usize>,
    data: Vec<f32>,
}

impl Tensor {
    pub fn new(shape: Vec<usize>, data: Vec<f32>) -> Result<Self> {
        let n: usize = shape.iter().product();
        if n != data.len() {
            return Err(Error::shape(format!(
                "shape {:?} wants {} elements, got {}",
                shape,
                n,
                data.len()
            )));
        }
        Ok(Tensor { shape, data })
    }

    pub fn zeros(shape: Vec<usize>) -> Self {
        let n = shape.iter().product();
        Tensor { shape, data: vec![0.0; n] }
    }

    pub fn full(shape: Vec<usize>, v: f32) -> Self {
        let n = shape.iter().product();
        Tensor { shape, data: vec![v; n] }
    }

    pub fn from_fn(shape: Vec<usize>, mut f: impl FnMut(usize) -> f32) -> Self {
        let n: usize = shape.iter().product();
        Tensor { shape, data: (0..n).map(|i| f(i)).collect() }
    }

    pub fn scalar(v: f32) -> Self {
        Tensor { shape: vec![], data: vec![v] }
    }

    pub fn shape(&self) -> &[usize] {
        &self.shape
    }

    pub fn numel(&self) -> usize {
        self.data.len()
    }

    pub fn data(&self) -> &[f32] {
        &self.data
    }

    pub fn data_mut(&mut self) -> &mut [f32] {
        &mut self.data
    }

    pub fn into_data(self) -> Vec<f32> {
        self.data
    }

    /// Reinterpret with a new shape of equal element count.
    pub fn reshaped(mut self, shape: Vec<usize>) -> Result<Self> {
        let n: usize = shape.iter().product();
        if n != self.data.len() {
            return Err(Error::shape(format!(
                "cannot reshape {} elements to {:?}",
                self.data.len(),
                shape
            )));
        }
        self.shape = shape;
        Ok(self)
    }

    fn check_same_shape(&self, other: &Tensor) -> Result<()> {
        if self.shape != other.shape {
            return Err(Error::shape(format!(
                "shape mismatch {:?} vs {:?}",
                self.shape, other.shape
            )));
        }
        Ok(())
    }

    // ------------------------------------------------------------------
    // In-place math (the optimizer hot path — no allocation)
    // ------------------------------------------------------------------

    /// self += alpha * x
    pub fn axpy(&mut self, alpha: f32, x: &Tensor) -> Result<()> {
        self.check_same_shape(x)?;
        for (a, b) in self.data.iter_mut().zip(&x.data) {
            *a += alpha * b;
        }
        Ok(())
    }

    /// self *= alpha
    pub fn scale(&mut self, alpha: f32) {
        for a in &mut self.data {
            *a *= alpha;
        }
    }

    /// self = alpha*self + beta*x  (fused; used by momentum updates)
    pub fn axpby(&mut self, alpha: f32, beta: f32, x: &Tensor) -> Result<()> {
        self.check_same_shape(x)?;
        for (a, b) in self.data.iter_mut().zip(&x.data) {
            *a = alpha * *a + beta * b;
        }
        Ok(())
    }

    /// self = (1-t)*self + t*x — linear interpolation (landscape planes,
    /// running BN stats).
    pub fn lerp(&mut self, t: f32, x: &Tensor) -> Result<()> {
        self.axpby(1.0 - t, t, x)
    }

    pub fn fill(&mut self, v: f32) {
        self.data.iter_mut().for_each(|a| *a = v);
    }

    // ------------------------------------------------------------------
    // Reductions / geometry
    // ------------------------------------------------------------------

    pub fn dot(&self, other: &Tensor) -> Result<f64> {
        self.check_same_shape(other)?;
        Ok(self
            .data
            .iter()
            .zip(&other.data)
            .map(|(a, b)| *a as f64 * *b as f64)
            .sum())
    }

    pub fn sq_norm(&self) -> f64 {
        self.data.iter().map(|a| *a as f64 * *a as f64).sum()
    }

    pub fn norm(&self) -> f64 {
        self.sq_norm().sqrt()
    }

    pub fn max_abs(&self) -> f32 {
        self.data.iter().fold(0.0f32, |m, a| m.max(a.abs()))
    }

    pub fn mean(&self) -> f64 {
        if self.data.is_empty() {
            return 0.0;
        }
        self.data.iter().map(|a| *a as f64).sum::<f64>() / self.data.len() as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn new_validates_shape() {
        assert!(Tensor::new(vec![2, 3], vec![0.0; 6]).is_ok());
        assert!(Tensor::new(vec![2, 3], vec![0.0; 5]).is_err());
    }

    #[test]
    fn scalar_and_zeros() {
        let s = Tensor::scalar(2.5);
        assert_eq!(s.numel(), 1);
        assert_eq!(s.shape(), &[] as &[usize]);
        let z = Tensor::zeros(vec![4, 4]);
        assert_eq!(z.numel(), 16);
        assert!(z.data().iter().all(|&x| x == 0.0));
    }

    #[test]
    fn axpy_and_scale() {
        let mut a = Tensor::new(vec![3], vec![1.0, 2.0, 3.0]).unwrap();
        let b = Tensor::new(vec![3], vec![10.0, 20.0, 30.0]).unwrap();
        a.axpy(0.5, &b).unwrap();
        assert_eq!(a.data(), &[6.0, 12.0, 18.0]);
        a.scale(2.0);
        assert_eq!(a.data(), &[12.0, 24.0, 36.0]);
    }

    #[test]
    fn axpy_shape_mismatch_errors() {
        let mut a = Tensor::zeros(vec![3]);
        let b = Tensor::zeros(vec![4]);
        assert!(a.axpy(1.0, &b).is_err());
    }

    #[test]
    fn axpby_momentum_semantics() {
        // m = mu*m + g
        let mut m = Tensor::new(vec![2], vec![1.0, -1.0]).unwrap();
        let g = Tensor::new(vec![2], vec![0.5, 0.5]).unwrap();
        m.axpby(0.9, 1.0, &g).unwrap();
        assert!((m.data()[0] - 1.4).abs() < 1e-6);
        assert!((m.data()[1] + 0.4).abs() < 1e-6);
    }

    #[test]
    fn lerp_endpoints() {
        let a0 = Tensor::new(vec![2], vec![0.0, 10.0]).unwrap();
        let b = Tensor::new(vec![2], vec![4.0, 2.0]).unwrap();
        let mut a = a0.clone();
        a.lerp(0.0, &b).unwrap();
        assert_eq!(a.data(), a0.data());
        a.lerp(1.0, &b).unwrap();
        assert_eq!(a.data(), b.data());
    }

    #[test]
    fn dot_norm_geometry() {
        let a = Tensor::new(vec![2], vec![3.0, 4.0]).unwrap();
        assert_eq!(a.norm(), 5.0);
        let b = Tensor::new(vec![2], vec![4.0, -3.0]).unwrap();
        assert_eq!(a.dot(&b).unwrap(), 0.0);
    }

    #[test]
    fn reshape_preserves_data() {
        let a = Tensor::new(vec![2, 3], (0..6).map(|i| i as f32).collect()).unwrap();
        let b = a.clone().reshaped(vec![3, 2]).unwrap();
        assert_eq!(b.shape(), &[3, 2]);
        assert_eq!(b.data(), a.data());
        assert!(a.reshaped(vec![4]).is_err());
    }

    #[test]
    fn mean_and_max_abs() {
        let a = Tensor::new(vec![4], vec![1.0, -5.0, 2.0, 2.0]).unwrap();
        assert_eq!(a.mean(), 0.0);
        assert_eq!(a.max_abs(), 5.0);
        assert_eq!(Tensor::zeros(vec![0]).mean(), 0.0);
    }
}
