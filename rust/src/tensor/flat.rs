//! Flat weight-space kernels: the arithmetic every hot path outside the
//! forward/backward pass runs on — the fused SGD/Nesterov step, phase-3
//! averaging, and the landscape geometry — expressed over contiguous
//! `&[f32]` arenas instead of ragged tensor lists.
//!
//! Determinism contract (same as `coordinator::parallel`): every kernel
//! produces bitwise-identical results for every `threads` value.
//! * Elementwise kernels (`axpy`, `scale`, `sgd_step`, `mean_into`) compute
//!   each element independently, so chunking the arena across threads
//!   cannot change any bit.
//! * Reductions (`dot_ranges`, `sq_norm_ranges`, `distance_ranges`) keep
//!   f64 partial sums per *layout range* (the per-tensor boundaries of the
//!   manifest, fixed at model-build time — NOT per thread chunk) and add
//!   the partials in range order. This reproduces the legacy per-tensor
//!   accumulation order of `tensor::ops::sets_dot` exactly, whatever the
//!   thread count.
//!
//! The elementwise kernels dispatch on the process-wide SIMD tier
//! (`util::simd`): the AVX2/NEON bodies assign whole elements to vector
//! lanes and keep multiply and add as two separately rounded instructions
//! (never FMA), so every tier is bitwise the scalar loop; a scalar tail
//! finishes the ragged remainder in element order. The reductions stay
//! scalar — f64 accumulation chains must not be split across lanes.
//!
//! Threading is gated per chunk via `coordinator::parallel::gate_per_chunk`
//! — a worker is only spawned if its own share of the work is worth a
//! spawn, so tiny vectors (and modest ones at high thread counts) never
//! pay for idle threads. Purely a wall-time knob: every kernel here is
//! bitwise identical for any worker count.

use std::ops::Range;

use crate::coordinator::parallel;
use crate::util::simd::{self, Tier};

#[cfg(target_arch = "x86_64")]
use std::arch::x86_64::{
    _mm256_add_ps, _mm256_loadu_ps, _mm256_mul_ps, _mm256_set1_ps, _mm256_storeu_ps, _mm256_sub_ps,
};

#[cfg(target_arch = "aarch64")]
use std::arch::aarch64::{vaddq_f32, vdupq_n_f32, vld1q_f32, vmulq_f32, vst1q_f32, vsubq_f32};

/// acc += alpha * x, chunk-parallel.
pub fn axpy(threads: usize, acc: &mut [f32], alpha: f32, x: &[f32]) {
    assert_eq!(acc.len(), x.len(), "axpy: length mismatch");
    let tier = simd::active();
    let t = parallel::gate_per_chunk(threads, acc.len() * 2, parallel::MIN_ITEM_WORK);
    parallel::parallel_row_chunks(t, acc, 1, |first, chunk| {
        axpy_chunk(tier, chunk, alpha, &x[first..first + chunk.len()]);
    });
}

/// acc += x, chunk-parallel — the streaming-accumulation kernel of the
/// averaging policies. A running sum built by one `add` per candidate (in
/// observation order) followed by a single `scale(1/n)` reproduces
/// `mean_into`'s accumulation order `((s0 + s1) + s2 + ...) * (1/n)`
/// element for element, so a streaming mean is bitwise-identical to the
/// terminal mean without retaining the candidates. (An incremental
/// `avg += (x - avg)/n` update would NOT be.)
pub fn add(threads: usize, acc: &mut [f32], x: &[f32]) {
    assert_eq!(acc.len(), x.len(), "add: length mismatch");
    let tier = simd::active();
    let t = parallel::gate_per_chunk(threads, acc.len() * 2, parallel::MIN_ITEM_WORK);
    parallel::parallel_row_chunks(t, acc, 1, |first, chunk| {
        add_chunk(tier, chunk, &x[first..first + chunk.len()]);
    });
}

/// acc *= alpha, chunk-parallel.
pub fn scale(threads: usize, acc: &mut [f32], alpha: f32) {
    let tier = simd::active();
    let t = parallel::gate_per_chunk(threads, acc.len(), parallel::MIN_ITEM_WORK);
    parallel::parallel_row_chunks(t, acc, 1, |_, chunk| {
        scale_chunk(tier, chunk, alpha);
    });
}

/// out = elementwise mean of `sets`, chunk-parallel and allocation-free:
/// out[i] = ((s0[i] + s1[i]) + s2[i] + ...) * (1/W) — the exact add order
/// of the legacy `tensor::ops::average_sets`, so the two agree bitwise.
pub fn mean_into(threads: usize, out: &mut [f32], sets: &[&[f32]]) {
    assert!(!sets.is_empty(), "mean_into: no sets");
    for s in sets {
        assert_eq!(s.len(), out.len(), "mean_into: length mismatch");
    }
    let inv = 1.0 / sets.len() as f32;
    let tier = simd::active();
    let t =
        parallel::gate_per_chunk(threads, out.len() * (sets.len() + 1), parallel::MIN_ITEM_WORK);
    parallel::parallel_row_chunks(t, out, 1, |first, chunk| {
        let end = first + chunk.len();
        chunk.copy_from_slice(&sets[0][first..end]);
        for s in &sets[1..] {
            add_chunk(tier, chunk, &s[first..end]);
        }
        scale_chunk(tier, chunk, inv);
    });
}

/// Fused SGD + Nesterov momentum + coupled weight decay over the whole
/// arena (the phase-1/phase-2 optimizer update; see `optim::sgd`):
///
/// ```text
/// g' = g + wd * p;  m' = mu * m + g';  p' = p - lr * (g' + mu * m')
/// ```
///
/// Elementwise, hence bitwise-identical for any `threads` and to the
/// per-tensor legacy loop.
pub fn sgd_step(
    threads: usize,
    p: &mut [f32],
    m: &mut [f32],
    g: &[f32],
    lr: f32,
    mu: f32,
    wd: f32,
) {
    assert_eq!(p.len(), m.len(), "sgd_step: momentum length mismatch");
    assert_eq!(p.len(), g.len(), "sgd_step: gradient length mismatch");
    let tier = simd::active();
    let t = parallel::gate_per_chunk(threads, p.len() * 6, parallel::MIN_ITEM_WORK);
    parallel::parallel_row_chunks2(t, p, m, 1, 1, |first, pc, mc| {
        sgd_chunk(tier, pc, mc, &g[first..first + pc.len()], lr, mu, wd);
    });
}

/// sum over ranges of <a[r], b[r]> in f64 — partials per layout range,
/// combined in range order (thread-count independent).
pub fn dot_ranges(threads: usize, a: &[f32], b: &[f32], ranges: &[Range<usize>]) -> f64 {
    assert_eq!(a.len(), b.len(), "dot_ranges: length mismatch");
    let t = parallel::gate_per_chunk(threads, a.len() * 2, parallel::MIN_ITEM_WORK);
    let partials = parallel::parallel_map(t, ranges.to_vec(), |_, r| {
        a[r.clone()]
            .iter()
            .zip(&b[r])
            .map(|(x, y)| *x as f64 * *y as f64)
            .sum::<f64>()
    });
    partials.into_iter().sum()
}

/// Squared Euclidean norm with per-range f64 partials.
pub fn sq_norm_ranges(threads: usize, a: &[f32], ranges: &[Range<usize>]) -> f64 {
    let t = parallel::gate_per_chunk(threads, a.len(), parallel::MIN_ITEM_WORK);
    let partials = parallel::parallel_map(t, ranges.to_vec(), |_, r| {
        a[r].iter().map(|x| *x as f64 * *x as f64).sum::<f64>()
    });
    partials.into_iter().sum()
}

/// Euclidean distance with per-range f64 partials — like its sibling
/// reductions, one partial per layout range combined in range order, so
/// the result is bitwise identical for every `threads` value (and to the
/// legacy sequential `sets_distance` accumulation order).
pub fn distance_ranges(threads: usize, a: &[f32], b: &[f32], ranges: &[Range<usize>]) -> f64 {
    assert_eq!(a.len(), b.len(), "distance_ranges: length mismatch");
    let t = parallel::gate_per_chunk(threads, a.len() * 2, parallel::MIN_ITEM_WORK);
    let partials = parallel::parallel_map(t, ranges.to_vec(), |_, r| {
        a[r.clone()]
            .iter()
            .zip(&b[r])
            .map(|(p, q)| {
                let d = (*p - *q) as f64;
                d * d
            })
            .sum::<f64>()
    });
    partials.into_iter().sum::<f64>().sqrt()
}

// ---------------------------------------------------------------------------
// per-chunk dispatch bodies. Each vector body processes the 8-element
// (AVX2) or 4-element (NEON) prefix and returns how far it got; the
// scalar tail finishes the remainder in element order. Unavailable tiers
// fall through to the scalar loop (`done = 0`).
// ---------------------------------------------------------------------------

fn axpy_chunk(tier: Tier, acc: &mut [f32], alpha: f32, x: &[f32]) {
    let done = match tier {
        // SAFETY: gated on runtime avx2 detection; the helper stays
        // inside acc/x, whose lengths match (asserted by the caller).
        #[cfg(target_arch = "x86_64")]
        Tier::Avx2 => unsafe { axpy_avx2(acc, alpha, x) },
        // SAFETY: gated on runtime neon detection, same bounds contract.
        #[cfg(target_arch = "aarch64")]
        Tier::Neon => unsafe { axpy_neon(acc, alpha, x) },
        _ => 0,
    };
    for (a, &b) in acc[done..].iter_mut().zip(&x[done..]) {
        *a += alpha * b;
    }
}

fn add_chunk(tier: Tier, acc: &mut [f32], x: &[f32]) {
    let done = match tier {
        // SAFETY: gated on runtime avx2 detection; in bounds as above.
        #[cfg(target_arch = "x86_64")]
        Tier::Avx2 => unsafe { add_avx2(acc, x) },
        // SAFETY: gated on runtime neon detection; in bounds as above.
        #[cfg(target_arch = "aarch64")]
        Tier::Neon => unsafe { add_neon(acc, x) },
        _ => 0,
    };
    for (a, &b) in acc[done..].iter_mut().zip(&x[done..]) {
        *a += b;
    }
}

fn scale_chunk(tier: Tier, acc: &mut [f32], alpha: f32) {
    let done = match tier {
        // SAFETY: gated on runtime avx2 detection; in bounds as above.
        #[cfg(target_arch = "x86_64")]
        Tier::Avx2 => unsafe { scale_avx2(acc, alpha) },
        // SAFETY: gated on runtime neon detection; in bounds as above.
        #[cfg(target_arch = "aarch64")]
        Tier::Neon => unsafe { scale_neon(acc, alpha) },
        _ => 0,
    };
    for a in acc[done..].iter_mut() {
        *a *= alpha;
    }
}

fn sgd_chunk(tier: Tier, pc: &mut [f32], mc: &mut [f32], gc: &[f32], lr: f32, mu: f32, wd: f32) {
    let done = match tier {
        // SAFETY: gated on runtime avx2 detection; pc/mc/gc lengths
        // match (asserted by the caller).
        #[cfg(target_arch = "x86_64")]
        Tier::Avx2 => unsafe { sgd_avx2(pc, mc, gc, lr, mu, wd) },
        // SAFETY: gated on runtime neon detection, same bounds contract.
        #[cfg(target_arch = "aarch64")]
        Tier::Neon => unsafe { sgd_neon(pc, mc, gc, lr, mu, wd) },
        _ => 0,
    };
    for i in done..pc.len() {
        let g2 = gc[i] + wd * pc[i];
        let m2 = mu * mc[i] + g2;
        pc[i] -= lr * (g2 + mu * m2);
        mc[i] = m2;
    }
}

// ---------------------------------------------------------------------------
// AVX2 bodies (x86_64). Lane j holds element i+j; multiply and add are
// separate instructions (two roundings — the scalar op sequence, never
// FMA), so each lane replays its element's scalar chain exactly.
// ---------------------------------------------------------------------------

#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "avx2")]
unsafe fn axpy_avx2(acc: &mut [f32], alpha: f32, x: &[f32]) -> usize {
    let n8 = acc.len() & !7;
    let av = _mm256_set1_ps(alpha);
    let mut i = 0;
    while i < n8 {
        let a = _mm256_loadu_ps(acc.as_ptr().add(i));
        let b = _mm256_loadu_ps(x.as_ptr().add(i));
        _mm256_storeu_ps(acc.as_mut_ptr().add(i), _mm256_add_ps(a, _mm256_mul_ps(av, b)));
        i += 8;
    }
    n8
}

#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "avx2")]
unsafe fn add_avx2(acc: &mut [f32], x: &[f32]) -> usize {
    let n8 = acc.len() & !7;
    let mut i = 0;
    while i < n8 {
        let a = _mm256_loadu_ps(acc.as_ptr().add(i));
        let b = _mm256_loadu_ps(x.as_ptr().add(i));
        _mm256_storeu_ps(acc.as_mut_ptr().add(i), _mm256_add_ps(a, b));
        i += 8;
    }
    n8
}

#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "avx2")]
unsafe fn scale_avx2(acc: &mut [f32], alpha: f32) -> usize {
    let n8 = acc.len() & !7;
    let av = _mm256_set1_ps(alpha);
    let mut i = 0;
    while i < n8 {
        let a = _mm256_loadu_ps(acc.as_ptr().add(i));
        _mm256_storeu_ps(acc.as_mut_ptr().add(i), _mm256_mul_ps(a, av));
        i += 8;
    }
    n8
}

#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "avx2")]
unsafe fn sgd_avx2(pc: &mut [f32], mc: &mut [f32], gc: &[f32], lr: f32, mu: f32, wd: f32) -> usize {
    let n8 = pc.len() & !7;
    let (lrv, muv, wdv) = (_mm256_set1_ps(lr), _mm256_set1_ps(mu), _mm256_set1_ps(wd));
    let mut i = 0;
    while i < n8 {
        let p = _mm256_loadu_ps(pc.as_ptr().add(i));
        let m = _mm256_loadu_ps(mc.as_ptr().add(i));
        let g = _mm256_loadu_ps(gc.as_ptr().add(i));
        let g2 = _mm256_add_ps(g, _mm256_mul_ps(wdv, p));
        let m2 = _mm256_add_ps(_mm256_mul_ps(muv, m), g2);
        let step = _mm256_mul_ps(lrv, _mm256_add_ps(g2, _mm256_mul_ps(muv, m2)));
        _mm256_storeu_ps(pc.as_mut_ptr().add(i), _mm256_sub_ps(p, step));
        _mm256_storeu_ps(mc.as_mut_ptr().add(i), m2);
        i += 8;
    }
    n8
}

// ---------------------------------------------------------------------------
// NEON bodies (aarch64) — same lane/rounding contract, 4 lanes.
// ---------------------------------------------------------------------------

#[cfg(target_arch = "aarch64")]
#[target_feature(enable = "neon")]
unsafe fn axpy_neon(acc: &mut [f32], alpha: f32, x: &[f32]) -> usize {
    let n4 = acc.len() & !3;
    let av = vdupq_n_f32(alpha);
    let mut i = 0;
    while i < n4 {
        let a = vld1q_f32(acc.as_ptr().add(i));
        let b = vld1q_f32(x.as_ptr().add(i));
        vst1q_f32(acc.as_mut_ptr().add(i), vaddq_f32(a, vmulq_f32(av, b)));
        i += 4;
    }
    n4
}

#[cfg(target_arch = "aarch64")]
#[target_feature(enable = "neon")]
unsafe fn add_neon(acc: &mut [f32], x: &[f32]) -> usize {
    let n4 = acc.len() & !3;
    let mut i = 0;
    while i < n4 {
        let a = vld1q_f32(acc.as_ptr().add(i));
        let b = vld1q_f32(x.as_ptr().add(i));
        vst1q_f32(acc.as_mut_ptr().add(i), vaddq_f32(a, b));
        i += 4;
    }
    n4
}

#[cfg(target_arch = "aarch64")]
#[target_feature(enable = "neon")]
unsafe fn scale_neon(acc: &mut [f32], alpha: f32) -> usize {
    let n4 = acc.len() & !3;
    let av = vdupq_n_f32(alpha);
    let mut i = 0;
    while i < n4 {
        let a = vld1q_f32(acc.as_ptr().add(i));
        vst1q_f32(acc.as_mut_ptr().add(i), vmulq_f32(a, av));
        i += 4;
    }
    n4
}

#[cfg(target_arch = "aarch64")]
#[target_feature(enable = "neon")]
unsafe fn sgd_neon(pc: &mut [f32], mc: &mut [f32], gc: &[f32], lr: f32, mu: f32, wd: f32) -> usize {
    let n4 = pc.len() & !3;
    let (lrv, muv, wdv) = (vdupq_n_f32(lr), vdupq_n_f32(mu), vdupq_n_f32(wd));
    let mut i = 0;
    while i < n4 {
        let p = vld1q_f32(pc.as_ptr().add(i));
        let m = vld1q_f32(mc.as_ptr().add(i));
        let g = vld1q_f32(gc.as_ptr().add(i));
        let g2 = vaddq_f32(g, vmulq_f32(wdv, p));
        let m2 = vaddq_f32(vmulq_f32(muv, m), g2);
        let step = vmulq_f32(lrv, vaddq_f32(g2, vmulq_f32(muv, m2)));
        vst1q_f32(pc.as_mut_ptr().add(i), vsubq_f32(p, step));
        vst1q_f32(mc.as_mut_ptr().add(i), m2);
        i += 4;
    }
    n4
}

#[cfg(test)]
mod tests {
    use super::*;

    fn whole(n: usize) -> Vec<Range<usize>> {
        vec![0..n]
    }

    fn assert_bits(got: &[f32], want: &[f32], what: &str) {
        assert_eq!(got.len(), want.len(), "{what}: length");
        for (i, (g, w)) in got.iter().zip(want).enumerate() {
            assert_eq!(g.to_bits(), w.to_bits(), "{what}[{i}]: {g} vs {w}");
        }
    }

    #[test]
    fn axpy_scale_mean_elementwise() {
        let mut a = vec![1.0f32, 2.0, 3.0];
        axpy(1, &mut a, 0.5, &[10.0, 20.0, 30.0]);
        assert_eq!(a, vec![6.0, 12.0, 18.0]);
        scale(1, &mut a, 2.0);
        assert_eq!(a, vec![12.0, 24.0, 36.0]);
        let mut out = vec![0.0f32; 2];
        mean_into(1, &mut out, &[&[0.0, 4.0], &[2.0, 0.0]]);
        assert_eq!(out, vec![1.0, 2.0]);
    }

    #[test]
    fn simd_tiers_match_scalar_bitwise() {
        // an odd length exercises both the vector body and the scalar
        // tail of every dispatch tier this host can run
        let n = 1003;
        let x: Vec<f32> = (0..n).map(|i| (i as f32 * 0.31).sin() * 1.7).collect();
        let g: Vec<f32> = (0..n).map(|i| (i as f32 * 0.23).cos() * 0.9).collect();
        for tier in simd::tiers_available() {
            let mut want = x.clone();
            axpy_chunk(Tier::Scalar, &mut want, 1.37, &g);
            let mut got = x.clone();
            axpy_chunk(tier, &mut got, 1.37, &g);
            assert_bits(&got, &want, &format!("axpy {tier:?}"));

            let mut want = x.clone();
            add_chunk(Tier::Scalar, &mut want, &g);
            let mut got = x.clone();
            add_chunk(tier, &mut got, &g);
            assert_bits(&got, &want, &format!("add {tier:?}"));

            let mut want = x.clone();
            scale_chunk(Tier::Scalar, &mut want, 0.73);
            let mut got = x.clone();
            scale_chunk(tier, &mut got, 0.73);
            assert_bits(&got, &want, &format!("scale {tier:?}"));

            let (mut p1, mut m1) = (x.clone(), g.clone());
            sgd_chunk(Tier::Scalar, &mut p1, &mut m1, &g, 0.05, 0.9, 5e-4);
            let (mut p2, mut m2) = (x.clone(), g.clone());
            sgd_chunk(tier, &mut p2, &mut m2, &g, 0.05, 0.9, 5e-4);
            assert_bits(&p2, &p1, &format!("sgd p {tier:?}"));
            assert_bits(&m2, &m1, &format!("sgd m {tier:?}"));
        }
    }

    #[test]
    fn kernels_bitwise_identical_across_threads() {
        // big enough that the per-chunk gate actually spawns workers
        let n = 2_100_007;
        let a0: Vec<f32> = (0..n).map(|i| (i as f32 * 0.37).sin()).collect();
        let b: Vec<f32> = (0..n).map(|i| (i as f32 * 0.11).cos()).collect();
        let ranges = vec![0..100, 100..50_000, 50_000..n];
        let mut seq = a0.clone();
        axpy(1, &mut seq, 1.5, &b);
        let d_seq = dot_ranges(1, &seq, &b, &ranges);
        let n_seq = sq_norm_ranges(1, &seq, &ranges);
        let e_seq = distance_ranges(1, &seq, &b, &ranges);
        for threads in [2, 4, 7] {
            let mut par = a0.clone();
            axpy(threads, &mut par, 1.5, &b);
            assert_eq!(seq, par, "axpy threads={threads}");
            assert_eq!(
                d_seq.to_bits(),
                dot_ranges(threads, &par, &b, &ranges).to_bits(),
                "dot threads={threads}"
            );
            assert_eq!(
                n_seq.to_bits(),
                sq_norm_ranges(threads, &par, &ranges).to_bits(),
                "sq_norm threads={threads}"
            );
            assert_eq!(
                e_seq.to_bits(),
                distance_ranges(threads, &par, &b, &ranges).to_bits(),
                "distance threads={threads}"
            );
        }
    }

    #[test]
    fn sgd_step_matches_scalar_reference() {
        let (lr, mu, wd) = (0.2f32, 0.9f32, 0.01f32);
        let g = [0.3f32, -0.1, 0.05];
        let mut p = vec![1.0f32; 3];
        let mut m = vec![0.0f32; 3];
        sgd_step(1, &mut p, &mut m, &g, lr, mu, wd);
        for i in 0..3 {
            let g2 = g[i] + wd * 1.0;
            let m2 = mu * 0.0 + g2;
            let want = 1.0 - lr * (g2 + mu * m2);
            assert!((p[i] - want).abs() < 1e-7);
            assert!((m[i] - m2).abs() < 1e-7);
        }
    }

    #[test]
    fn sgd_step_threads_bitwise() {
        // crosses the per-chunk spawn gate (6n >= 2 * MIN_ITEM_WORK)
        let n = 400_003;
        let g: Vec<f32> = (0..n).map(|i| (i as f32 * 0.7).sin()).collect();
        let p0: Vec<f32> = (0..n).map(|i| (i as f32 * 0.3).cos()).collect();
        let mut p1 = p0.clone();
        let mut m1 = vec![0.1f32; n];
        sgd_step(1, &mut p1, &mut m1, &g, 0.05, 0.9, 5e-4);
        for threads in [2, 5] {
            let mut p2 = p0.clone();
            let mut m2 = vec![0.1f32; n];
            sgd_step(threads, &mut p2, &mut m2, &g, 0.05, 0.9, 5e-4);
            assert_eq!(p1, p2);
            assert_eq!(m1, m2);
        }
    }

    #[test]
    fn distance_and_dot_geometry() {
        let a = [3.0f32, 4.0];
        let z = [0.0f32, 0.0];
        assert_eq!(distance_ranges(1, &a, &z, &whole(2)), 5.0);
        assert_eq!(dot_ranges(1, &a, &a, &whole(2)), 25.0);
        let b = [4.0f32, -3.0];
        assert_eq!(dot_ranges(1, &a, &b, &whole(2)), 0.0);
    }

    #[test]
    fn mean_into_of_identical_is_identity() {
        let s = [1.5f32, -2.0, 0.25];
        let mut out = vec![0.0f32; 3];
        mean_into(1, &mut out, &[&s, &s, &s]);
        assert_eq!(out, s.to_vec());
    }
}
