//! Compile-only stub of the `xla` crate (see README.md).
//!
//! `Literal` carries real host data so conversion round-trips work; the
//! PJRT client/executable surface compiles but reports that XLA execution
//! is unavailable at runtime.

use std::fmt;

/// Stub error type (the real crate wraps XLA status codes).
#[derive(Debug)]
pub struct Error(pub String);

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.0)
    }
}

impl std::error::Error for Error {}

pub type Result<T> = std::result::Result<T, Error>;

fn unavailable<T>(what: &str) -> Result<T> {
    Err(Error(format!(
        "{what}: this build links the compile-only xla stub crate \
         (rust/vendor/xla); swap it for the real xla crate to execute \
         HLO artifacts"
    )))
}

/// Element types a stub literal can hold.
#[derive(Debug, Clone, PartialEq)]
enum Data {
    F32(Vec<f32>),
    I32(Vec<i32>),
}

impl Data {
    fn len(&self) -> usize {
        match self {
            Data::F32(v) => v.len(),
            Data::I32(v) => v.len(),
        }
    }
}

/// Marker trait for element types supported by the stub.
pub trait ElementType: Copy {
    fn wrap(data: &[Self]) -> Data;
    fn unwrap(data: &Data) -> Result<Vec<Self>>;
}

impl ElementType for f32 {
    fn wrap(data: &[Self]) -> Data {
        Data::F32(data.to_vec())
    }
    fn unwrap(data: &Data) -> Result<Vec<Self>> {
        match data {
            Data::F32(v) => Ok(v.clone()),
            _ => unavailable("Literal element type mismatch (want f32)"),
        }
    }
}

impl ElementType for i32 {
    fn wrap(data: &[Self]) -> Data {
        Data::I32(data.to_vec())
    }
    fn unwrap(data: &Data) -> Result<Vec<Self>> {
        match data {
            Data::I32(v) => Ok(v.clone()),
            _ => unavailable("Literal element type mismatch (want i32)"),
        }
    }
}

/// Host literal: shape + typed data.
#[derive(Debug, Clone, PartialEq)]
pub struct Literal {
    dims: Vec<i64>,
    data: Data,
}

impl Literal {
    pub fn vec1<T: ElementType>(data: &[T]) -> Literal {
        Literal { dims: vec![data.len() as i64], data: T::wrap(data) }
    }

    pub fn reshape(&self, dims: &[i64]) -> Result<Literal> {
        let n: i64 = dims.iter().product();
        if n as usize != self.data.len() {
            return Err(Error(format!(
                "reshape {:?} ({} elements) to {:?}",
                self.dims,
                self.data.len(),
                dims
            )));
        }
        Ok(Literal { dims: dims.to_vec(), data: self.data.clone() })
    }

    pub fn element_count(&self) -> usize {
        self.data.len()
    }

    pub fn to_vec<T: ElementType>(&self) -> Result<Vec<T>> {
        T::unwrap(&self.data)
    }

    pub fn get_first_element<T: ElementType>(&self) -> Result<T> {
        self.to_vec::<T>()?
            .first()
            .copied()
            .ok_or_else(|| Error("get_first_element on empty literal".into()))
    }

    pub fn array_shape(&self) -> Result<ArrayShape> {
        Ok(ArrayShape { dims: self.dims.clone() })
    }

    /// The stub never produces tuple literals (only real PJRT execution
    /// does), so decomposition always fails.
    pub fn to_tuple(self) -> Result<Vec<Literal>> {
        unavailable("Literal::to_tuple")
    }
}

/// Array shape (dims only — the stub is f32/i32 untyped here).
#[derive(Debug, Clone, PartialEq)]
pub struct ArrayShape {
    dims: Vec<i64>,
}

impl ArrayShape {
    pub fn dims(&self) -> &[i64] {
        &self.dims
    }
}

/// Stub PJRT client: constructible surface, unavailable at runtime.
pub struct PjRtClient;

impl PjRtClient {
    pub fn cpu() -> Result<Self> {
        unavailable("PjRtClient::cpu")
    }

    pub fn compile(&self, _comp: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        unavailable("PjRtClient::compile")
    }
}

pub struct PjRtLoadedExecutable;

impl PjRtLoadedExecutable {
    pub fn execute<L: std::borrow::Borrow<Literal>>(
        &self,
        _args: &[L],
    ) -> Result<Vec<Vec<PjRtBuffer>>> {
        unavailable("PjRtLoadedExecutable::execute")
    }
}

pub struct PjRtBuffer;

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        unavailable("PjRtBuffer::to_literal_sync")
    }
}

pub struct HloModuleProto;

impl HloModuleProto {
    pub fn from_text_file(_path: &str) -> Result<Self> {
        unavailable("HloModuleProto::from_text_file")
    }
}

pub struct XlaComputation;

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn literal_roundtrip_and_reshape() {
        let l = Literal::vec1(&[1.0f32, 2.0, 3.0, 4.0]);
        assert_eq!(l.element_count(), 4);
        let r = l.reshape(&[2, 2]).unwrap();
        assert_eq!(r.array_shape().unwrap().dims(), &[2, 2]);
        assert_eq!(r.to_vec::<f32>().unwrap(), vec![1.0, 2.0, 3.0, 4.0]);
        assert!(l.reshape(&[3]).is_err());
        assert!(r.to_vec::<i32>().is_err());
    }

    #[test]
    fn pjrt_surface_is_unavailable() {
        assert!(PjRtClient::cpu().is_err());
        assert!(HloModuleProto::from_text_file("x").is_err());
        let e = PjRtClient::cpu().unwrap_err();
        assert!(e.to_string().contains("stub"));
    }
}
