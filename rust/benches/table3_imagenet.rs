//! Bench: regenerate the paper's Table 3 (ImageNet — Top1/Top5, doubled
//! batch + doubled LR for the LB arm, 2 phase-2 worker groups of 2 devices).
//! Run: cargo bench --bench table3_imagenet

use swap::experiments::{tables, Lab};

fn main() -> swap::util::Result<()> {
    let lab = Lab::new(swap::config::preset("imagenetsim")?)?;
    let t = tables::table3(&lab)?;
    t.print();
    tables::save_table(&t, "table3")?;
    Ok(())
}
