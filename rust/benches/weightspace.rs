//! Weight-space arena bench: old-vs-new wall time for every hot path the
//! flat-arena refactor rewrote — fused SGD step, ring all-reduce, phase-3
//! averaging, and landscape plane-grid materialization — sequential and
//! chunk-parallel. Emits `BENCH_weightspace.json` (and a copy under
//! results/) with per-row timings plus legacy/flat speedups, and asserts
//! bitwise old-vs-new parity along the way.
//! Run: cargo bench --bench weightspace

use swap::bench::{bench, env_manifest, Stats, Table};
use swap::coordinator::{allreduce, parallel};
use swap::landscape::Plane;
use swap::model::{FlatParams, ParamSet};
use swap::runtime::native::{native_manifest, NativeSpec};
use swap::tensor::{self, flat, Tensor};
use swap::util::{Json, Result};

const W: usize = 8;
const GRID_POINTS: usize = 16;

fn flatten(tensors: &[Tensor]) -> Vec<f32> {
    let mut out = Vec::new();
    for t in tensors {
        out.extend_from_slice(t.data());
    }
    out
}

/// The pre-refactor per-tensor optimizer loop (legacy reference).
fn legacy_sgd_step(params: &mut [Tensor], momentum: &mut [Tensor], grads: &[Tensor], lr: f32) {
    let (mu, wd) = (0.9f32, 5e-4f32);
    for ((p, m), g) in params.iter_mut().zip(momentum.iter_mut()).zip(grads) {
        let (pd, md, gd) = (p.data_mut(), m.data_mut(), g.data());
        for i in 0..pd.len() {
            let g2 = gd[i] + wd * pd[i];
            let m2 = mu * md[i] + g2;
            pd[i] -= lr * (g2 + mu * m2);
            md[i] = m2;
        }
    }
}

/// The pre-refactor `ParamSet::average`: a W-way deep clone feeding the
/// per-tensor `average_sets` (legacy reference).
fn legacy_average(sets: &[Vec<Tensor>]) -> Vec<Tensor> {
    let slices: Vec<Vec<Tensor>> = sets.to_vec();
    tensor::average_sets(&slices).unwrap()
}

struct Row {
    op: &'static str,
    impl_name: &'static str,
    threads: usize,
    stats: Stats,
}

fn main() -> Result<()> {
    let m = native_manifest(&NativeSpec::new("weightspace", 16, 10, 32));
    let threads = parallel::default_threads().max(2);
    let n = m.num_params;
    println!("weightspace bench: {} params, W={W}, threads={threads}", n);

    // W model-shaped weight vectors, both representations
    let models: Vec<ParamSet> = (0..W).map(|w| ParamSet::init(&m, w as u64)).collect();
    let tensor_sets: Vec<Vec<Tensor>> = models.iter().map(|p| p.to_tensors()).collect();

    let mut rows: Vec<Row> = Vec::new();

    // ---- fused SGD step ------------------------------------------------
    let grads_flat = models[1].data().to_vec();
    let grads_t = tensor_sets[1].clone();
    let step_legacy = {
        let mut p = tensor_sets[0].clone();
        let mut mom: Vec<Tensor> = p.iter().map(|t| Tensor::zeros(t.shape().to_vec())).collect();
        bench(3, 30, || legacy_sgd_step(&mut p, &mut mom, &grads_t, 0.01))
    };
    rows.push(Row { op: "step", impl_name: "legacy", threads: 1, stats: step_legacy });
    let step_flat_seq = {
        let mut p = models[0].clone();
        let mut mom = p.zeros_like();
        bench(3, 30, || {
            flat::sgd_step(1, p.as_mut_slice(), mom.as_mut_slice(), &grads_flat, 0.01, 0.9, 5e-4)
        })
    };
    rows.push(Row { op: "step", impl_name: "flat", threads: 1, stats: step_flat_seq });
    let step_flat_par = {
        let mut p = models[0].clone();
        let mut mom = p.zeros_like();
        bench(3, 30, || {
            flat::sgd_step(
                threads,
                p.as_mut_slice(),
                mom.as_mut_slice(),
                &grads_flat,
                0.01,
                0.9,
                5e-4,
            )
        })
    };
    rows.push(Row { op: "step", impl_name: "flat", threads, stats: step_flat_par });

    // parity: one legacy step vs one flat step, bitwise
    {
        let mut lp = tensor_sets[0].clone();
        let mut lm: Vec<Tensor> =
            lp.iter().map(|t| Tensor::zeros(t.shape().to_vec())).collect();
        legacy_sgd_step(&mut lp, &mut lm, &grads_t, 0.01);
        let mut fp = models[0].clone();
        let mut fm = fp.zeros_like();
        flat::sgd_step(1, fp.as_mut_slice(), fm.as_mut_slice(), &grads_flat, 0.01, 0.9, 5e-4);
        assert_eq!(fp.data(), flatten(&lp).as_slice(), "step parity");
    }

    // ---- ring all-reduce -----------------------------------------------
    let ring_legacy = bench(2, 15, || {
        allreduce::ring_mean_reference(&tensor_sets).unwrap();
    });
    rows.push(Row { op: "ring", impl_name: "legacy", threads: 1, stats: ring_legacy });
    let ring_flat = {
        // in-place: each run reduces the previous run's buffers — values
        // grow but the arithmetic (and its wall time) is identical
        let mut bufs: Vec<Vec<f32>> = models.iter().map(|p| p.data().to_vec()).collect();
        bench(2, 15, || {
            allreduce::ring_mean_inplace(&mut bufs).unwrap();
        })
    };
    rows.push(Row { op: "ring", impl_name: "flat", threads: 1, stats: ring_flat });

    // parity: flat in-place ring equals the legacy ring bitwise
    {
        let reference = allreduce::ring_mean_reference(&tensor_sets).unwrap();
        let mut bufs: Vec<Vec<f32>> = models.iter().map(|p| p.data().to_vec()).collect();
        allreduce::ring_mean_inplace(&mut bufs).unwrap();
        assert_eq!(bufs[0], flatten(&reference), "ring parity");
    }

    // ---- phase-3 averaging ----------------------------------------------
    let avg_legacy = bench(2, 20, || {
        legacy_average(&tensor_sets);
    });
    rows.push(Row { op: "average", impl_name: "legacy", threads: 1, stats: avg_legacy });
    let avg_flat_seq = bench(2, 20, || {
        FlatParams::average_mt(&models, 1).unwrap();
    });
    rows.push(Row { op: "average", impl_name: "flat", threads: 1, stats: avg_flat_seq });
    let avg_flat_par = bench(2, 20, || {
        FlatParams::average_mt(&models, threads).unwrap();
    });
    rows.push(Row { op: "average", impl_name: "flat", threads, stats: avg_flat_par });

    // parity
    assert_eq!(
        FlatParams::average_mt(&models, threads).unwrap().data(),
        flatten(&legacy_average(&tensor_sets)).as_slice(),
        "average parity"
    );

    // ---- plane grid materialization -------------------------------------
    let plane = Plane::through(&models[0], &models[1], &models[2]).unwrap();
    // the same three anchors in the legacy per-tensor representation
    let (t1_t, t2_t, t3_t) = (&tensor_sets[0], &tensor_sets[1], &tensor_sets[2]);
    let lo = t1_t.clone();
    // legacy basis: the pre-refactor sets_* pipeline
    let legacy_u;
    let legacy_v;
    {
        let d2 = tensor::sets_sub(t2_t, t1_t).unwrap();
        let d3 = tensor::sets_sub(t3_t, t1_t).unwrap();
        let n2 = tensor::sets_norm(&d2);
        let mut u = d2;
        tensor::sets_scale(&mut u, (1.0 / n2) as f32);
        let a3 = tensor::sets_dot(&d3, &u).unwrap();
        let mut v = d3;
        tensor::sets_axpy(&mut v, -a3 as f32, &u).unwrap();
        let nv = tensor::sets_norm(&v);
        tensor::sets_scale(&mut v, (1.0 / nv) as f32);
        legacy_u = u;
        legacy_v = v;
    }
    let plane_legacy = bench(1, 10, || {
        for k in 0..GRID_POINTS {
            let alpha = k as f64 * 0.1;
            let mut t = lo.clone();
            tensor::sets_axpy(&mut t, alpha as f32, &legacy_u).unwrap();
            tensor::sets_axpy(&mut t, 0.5, &legacy_v).unwrap();
        }
    });
    rows.push(Row { op: "plane_grid", impl_name: "legacy", threads: 1, stats: plane_legacy });
    let plane_flat_seq = bench(1, 10, || {
        for k in 0..GRID_POINTS {
            plane.point_mt(k as f64 * 0.1, 0.5, 1).unwrap();
        }
    });
    rows.push(Row { op: "plane_grid", impl_name: "flat", threads: 1, stats: plane_flat_seq });
    let plane_flat_par = bench(1, 10, || {
        for k in 0..GRID_POINTS {
            plane.point_mt(k as f64 * 0.1, 0.5, threads).unwrap();
        }
    });
    rows.push(Row { op: "plane_grid", impl_name: "flat", threads, stats: plane_flat_par });

    // ---- report ----------------------------------------------------------
    let mut t = Table::new(
        &format!("weight-space arena: legacy vs flat ({n} params, W={W})"),
        &["op", "impl", "threads", "mean (ms)", "std (ms)", "min (ms)"],
    );
    for r in &rows {
        t.row(&[
            r.op.to_string(),
            r.impl_name.to_string(),
            r.threads.to_string(),
            format!("{:.3}", r.stats.mean * 1e3),
            format!("{:.3}", r.stats.std * 1e3),
            format!("{:.3}", r.stats.min * 1e3),
        ]);
    }
    t.print();

    let seq_mean = |op: &str, imp: &str| -> f64 {
        rows.iter()
            .find(|r| r.op == op && r.impl_name == imp && r.threads == 1)
            .map(|r| r.stats.mean)
            .unwrap_or(f64::NAN)
    };
    let speedup = |op: &str| seq_mean(op, "legacy") / seq_mean(op, "flat").max(1e-12);
    let (s_step, s_ring, s_avg, s_plane) = (
        speedup("step"),
        speedup("ring"),
        speedup("average"),
        speedup("plane_grid"),
    );
    println!(
        "legacy/flat speedups (sequential): step {s_step:.2}x | ring {s_ring:.2}x | \
         average {s_avg:.2}x | plane {s_plane:.2}x"
    );

    let json_rows: Vec<Json> = rows
        .iter()
        .map(|r| {
            Json::obj(vec![
                ("op", Json::Str(r.op.to_string())),
                ("impl", Json::Str(r.impl_name.to_string())),
                ("threads", Json::Num(r.threads as f64)),
                ("mean_seconds", Json::Num(r.stats.mean)),
                ("std_seconds", Json::Num(r.stats.std)),
                ("min_seconds", Json::Num(r.stats.min)),
            ])
        })
        .collect();
    let json = Json::obj(vec![
        ("bench", Json::Str("weightspace".to_string())),
        ("environment", env_manifest()),
        ("num_params", Json::Num(n as f64)),
        ("workers", Json::Num(W as f64)),
        ("threads_parallel", Json::Num(threads as f64)),
        ("rows", Json::Arr(json_rows)),
        (
            "speedups",
            Json::obj(vec![
                ("step", Json::Num(s_step)),
                ("ring", Json::Num(s_ring)),
                ("average", Json::Num(s_avg)),
                ("plane_grid", Json::Num(s_plane)),
            ]),
        ),
    ])
    .to_string_pretty();
    std::fs::write("BENCH_weightspace.json", &json)?;
    std::fs::create_dir_all("results")?;
    std::fs::write("results/BENCH_weightspace.json", &json)?;
    println!("wrote BENCH_weightspace.json");
    Ok(())
}
