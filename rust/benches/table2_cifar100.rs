//! Bench: regenerate the paper's Table 2 (CIFAR100 — SB vs LB vs SWAP).
//! Run: cargo bench --bench table2_cifar100

use swap::experiments::{tables, Lab};

fn main() -> swap::util::Result<()> {
    let lab = Lab::new(swap::config::preset("cifar100sim")?)?;
    let t = tables::table2(&lab)?;
    t.print();
    tables::save_table(&t, "table2")?;
    Ok(())
}
