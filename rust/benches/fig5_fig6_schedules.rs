//! Bench: regenerate Figures 5 and 6 — the ImageNet LR/batch schedules
//! (original vs doubled vs the SWAP composition) and the SWA cyclic-LR
//! illustrations. Pure schedule evaluation; writes results/fig{5,6}_*.csv.
//! Run: cargo bench --bench fig5_fig6_schedules

use swap::experiments::{figures, Lab};

fn main() -> swap::util::Result<()> {
    let lab = Lab::new(swap::config::preset("imagenetsim")?)?;
    let f5 = figures::fig5(&lab)?;
    println!("fig5: {} rows (lr_original / lr_doubled / lr_swap + batch sizes)", f5.len());
    // the SWAP schedule must equal the doubled one early, the original late
    let (lrs, lrd, lro) = (
        f5.column("lr_swap").unwrap(),
        f5.column("lr_doubled").unwrap(),
        f5.column("lr_original").unwrap(),
    );
    let n = lrs.len();
    println!(
        "early: swap={:.4} doubled={:.4} | late: swap={:.4} original-tail={:.4}",
        lrs[n / 10], lrd[n / 10], lrs[n - 1], lro[5 * n / 28]
    );

    let lab100 = Lab::new(swap::config::preset("cifar100sim")?)?;
    let f6 = figures::fig6(&lab100)?;
    let markers: f64 = f6.column("sample_marker").unwrap().iter().sum();
    println!("fig6: {} rows, {} SWA sample points marked", f6.len(), markers);
    Ok(())
}
