//! Bench: regenerate the paper's Table 1 (CIFAR10 — SB vs LB vs SWAP).
//! Prints paper vs measured rows; writes results/table1.{txt,csv}.
//! Shape criteria (DESIGN.md): SWAP-after ≈ SB accuracy at ≈ LB-scale
//! time; averaging strictly helps over the mean worker.
//!
//! Run: cargo bench --bench table1_cifar10    (SWAP_RUNS=n overrides runs)

use swap::experiments::{tables, Lab};

fn main() -> swap::util::Result<()> {
    let lab = Lab::new(swap::config::preset("cifar10sim")?)?;
    let t = tables::table1(&lab)?;
    t.print();
    tables::save_table(&t, "table1")?;
    println!("shape check: SWAP(after) ≈ SB accuracy in ≈ LB-scale modeled time.");
    Ok(())
}
