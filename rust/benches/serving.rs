//! Serving smoke bench: open-loop latency/throughput for the dynamic
//! batcher on both numeric tiers, plus the int8-vs-f32 engine speedup.
//!
//! A width-16 model on 32x32 images is served by one shard (the CI
//! runner is effectively single-core) at three offered rates — 0.3/0.6/
//! 0.9 of the tier's measured batch-8 engine capacity — under synthetic
//! open-loop traffic: request `i` is *scheduled* at `i / rate` seconds
//! and its latency is measured from that scheduled arrival, so queueing
//! delay under load is part of the number (not hidden by client
//! back-off). Emits `BENCH_serving.json` (and a copy under results/)
//! with p50/p99 latency, sustained throughput and coalescing stats per
//! (tier, rate), stamped with an environment manifest.
//!
//! The int8 tier must beat f32 on raw engine throughput whenever a SIMD
//! tier is active (the i8 pair-MADD kernel does twice the k-depth per
//! instruction); the bench asserts it.
//! Run: cargo bench --bench serving

use std::sync::Arc;
use std::time::{Duration, Instant};

use swap::bench::env_manifest;
use swap::data::{Generator, SynthSpec};
use swap::model::{BnState, ParamSet};
use swap::runtime::native::{NativeBackend, NativeSpec};
use swap::runtime::Backend;
use swap::serving::{percentile, ServeConfig, ServeModel, ServeTier, Server, ShardEngine};
use swap::util::simd::{self, Tier};
use swap::util::{Json, Result};

const WIDTH: usize = 16;
const IMAGE: usize = 32;
const CLASSES: usize = 10;
const MAX_BATCH: usize = 8;
const MAX_DELAY_US: u64 = 500;
const REQUESTS: usize = 120;
const CLIENTS: usize = 8;
const N_IMGS: usize = 64;
const RATE_FRACS: [f64; 3] = [0.3, 0.6, 0.9];

fn build(tier: ServeTier) -> Result<Arc<ServeModel>> {
    let spec = NativeSpec::new("serving-bench", WIDTH, CLASSES, IMAGE).with_batches(&[MAX_BATCH]);
    let engine = NativeBackend::new(spec)?;
    let params = ParamSet::init(engine.manifest(), 7);
    let bn = BnState::init(engine.manifest());
    Ok(Arc::new(ServeModel::new(engine, params, bn, tier)?))
}

/// Best-of batch-8 engine throughput (images/sec) on the model's tier —
/// the serving capacity ceiling the offered rates are derived from.
fn engine_rps(model: &ServeModel, images: &[f32]) -> Result<f64> {
    let il = model.image_len();
    let mut eng = ShardEngine::new(model, MAX_BATCH);
    eng.warm(model)?;
    for j in 0..MAX_BATCH {
        eng.image_slot(j).copy_from_slice(&images[j * il..(j + 1) * il]);
    }
    let mut best = f64::INFINITY;
    for _ in 0..3 {
        let t0 = Instant::now();
        for _ in 0..4 {
            eng.infer(model, MAX_BATCH)?;
        }
        best = best.min(t0.elapsed().as_secs_f64());
    }
    Ok((4 * MAX_BATCH) as f64 / best)
}

/// Drive `REQUESTS` open-loop requests at `rate` req/s through `CLIENTS`
/// client threads; returns (p50_ms, p99_ms, throughput_rps).
fn open_loop(server: &Server, images: &[f32], rate: f64) -> (f64, f64, f64) {
    let il = server.model().image_len();
    let nc = server.model().num_classes();
    let start = Instant::now();
    let mut lats: Vec<f64> = Vec::with_capacity(REQUESTS);
    std::thread::scope(|s| {
        let mut handles = Vec::new();
        for c in 0..CLIENTS {
            handles.push(s.spawn(move || {
                let mut out = vec![0.0f32; nc];
                let mut mine = Vec::with_capacity(REQUESTS / CLIENTS + 1);
                for i in (c..REQUESTS).step_by(CLIENTS) {
                    let target = start + Duration::from_secs_f64(i as f64 / rate);
                    let now = Instant::now();
                    if target > now {
                        std::thread::sleep(target - now);
                    }
                    let at = i % N_IMGS;
                    let img = &images[at * il..(at + 1) * il];
                    server.classify_into(img, &mut out).expect("serve request failed");
                    mine.push(Instant::now().duration_since(target).as_secs_f64() * 1e3);
                }
                mine
            }));
        }
        for h in handles {
            lats.extend(h.join().unwrap());
        }
    });
    let wall = start.elapsed().as_secs_f64();
    lats.sort_by(f64::total_cmp);
    let p50 = percentile(&lats, 50.0);
    let p99 = percentile(&lats, 99.0);
    (p50, p99, REQUESTS as f64 / wall)
}

fn main() -> Result<()> {
    let gen = Generator::new(SynthSpec::for_preset(CLASSES, IMAGE, 5));
    let images = gen.sample(N_IMGS, CLASSES).images;
    let active = simd::active();
    println!(
        "serving bench: width {WIDTH} image {IMAGE} | 1 shard, max_batch {MAX_BATCH}, \
         max_delay {MAX_DELAY_US}us, {CLIENTS} clients (simd tier: {})",
        active.name()
    );

    // raw engine capacity per tier (batch 8, threads 1) — the int8 tier
    // must beat f32 whenever a vector tier is active
    let f32_model = build(ServeTier::F32)?;
    let int8_model = build(ServeTier::Int8)?;
    let f32_rps = engine_rps(&f32_model, &images)?;
    let int8_rps = engine_rps(&int8_model, &images)?;
    let speedup = int8_rps / f32_rps.max(1e-12);
    println!(
        "  engine t=1 batch {MAX_BATCH}: f32 {f32_rps:.0} img/s | int8 {int8_rps:.0} img/s \
         | int8 speedup {speedup:.2}x"
    );
    if active != Tier::Scalar {
        assert!(
            speedup > 1.0,
            "int8 engine throughput must beat f32 on SIMD tier {} ({speedup:.2}x)",
            active.name()
        );
    }

    let mut rows = Vec::new();
    for (model, capacity) in [(&f32_model, f32_rps), (&int8_model, int8_rps)] {
        let tier = model.tier;
        for frac in RATE_FRACS {
            let rate = (frac * capacity).max(1.0);
            let cfg = ServeConfig {
                shards: 1,
                max_batch: MAX_BATCH,
                max_delay: Duration::from_micros(MAX_DELAY_US),
                queue_slots: MAX_BATCH * 2,
            };
            // a fresh server per point: stats and warmup are per-combo
            let server = Server::start(model.clone(), cfg)?;
            let (p50, p99, tp) = open_loop(&server, &images, rate);
            let st = server.stats();
            assert_eq!(st.requests, REQUESTS as u64, "lost requests");
            assert_eq!(st.infer_errors, 0, "inference errors under load");
            // CLIENTS <= queue_slots: closed-loop clients can never shed
            assert_eq!(st.sheds, 0, "shed despite clients <= queue_slots");
            println!(
                "  {:<4} rate {frac:.1}x ({rate:>6.0} req/s offered) | p50 {p50:>7.2} ms \
                 | p99 {p99:>7.2} ms | {tp:>6.0} req/s | mean batch {:.2} (max {})",
                tier.name(),
                st.mean_batch(),
                st.max_batch_seen
            );
            rows.push(Json::obj(vec![
                ("tier", Json::str(tier.name())),
                ("rate_frac", Json::Num(frac)),
                ("offered_rps", Json::Num(rate)),
                ("requests", Json::Num(REQUESTS as f64)),
                ("p50_ms", Json::Num(p50)),
                ("p99_ms", Json::Num(p99)),
                ("throughput_rps", Json::Num(tp)),
                ("mean_batch", Json::Num(st.mean_batch())),
                ("max_batch_seen", Json::Num(st.max_batch_seen as f64)),
                ("sheds", Json::Num(st.sheds as f64)),
            ]));
        }
    }

    let json = Json::obj(vec![
        ("bench", Json::str("serving")),
        ("width", Json::Num(WIDTH as f64)),
        ("image_size", Json::Num(IMAGE as f64)),
        ("num_classes", Json::Num(CLASSES as f64)),
        ("shards", Json::Num(1.0)),
        ("max_batch", Json::Num(MAX_BATCH as f64)),
        ("max_delay_us", Json::Num(MAX_DELAY_US as f64)),
        ("clients", Json::Num(CLIENTS as f64)),
        (
            "engine_t1",
            Json::obj(vec![
                ("f32_imgs_per_s", Json::Num(f32_rps)),
                ("int8_imgs_per_s", Json::Num(int8_rps)),
                ("int8_speedup", Json::Num(speedup)),
                ("simd_tier", Json::str(active.name())),
            ]),
        ),
        ("environment", env_manifest()),
        ("rows", Json::Arr(rows)),
    ])
    .to_string_pretty();
    std::fs::write("BENCH_serving.json", &json)?;
    std::fs::create_dir_all("results")?;
    std::fs::write("results/BENCH_serving.json", &json)?;
    println!("wrote BENCH_serving.json");
    Ok(())
}
