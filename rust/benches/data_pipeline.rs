//! Input-pipeline bench: assembly throughput, synth vs disk source load
//! rates, pipeline overlap capacity (serial vs prefetched step time), and
//! the end-to-end training win. Emits `BENCH_data.json` (+ a copy under
//! results/) and asserts the bitwise contract along the way: prefetched
//! assembly must equal serial assembly exactly.
//! Run: cargo bench --bench data_pipeline

use swap::bench::{bench, env_manifest, time_once};
use swap::config::preset;
use swap::coordinator::{parallel, run_baseline, BaselineConfig};
use swap::data::{
    cifar, prefetch, AugStream, AugmentSpec, Batcher, CifarSource, CifarVariant, DataSource,
    Generator, SynthSpec,
};
use swap::experiments::Lab;
use swap::model::ParamSet;
use swap::optim::Schedule;
use swap::runtime::{Backend, HostBatch, NativeBackend, NativeSpec};
use swap::util::{Json, Result};

/// Write a deterministic CIFAR-10-format directory (for the disk-source
/// rows — the shared fixture pattern from `data::cifar::fixture_record`).
fn write_cifar_dir(dir: &std::path::Path, train: usize, test: usize) -> Result<()> {
    std::fs::create_dir_all(dir)?;
    let mut bytes = Vec::new();
    for i in 0..train {
        bytes.extend_from_slice(&cifar::fixture_record(CifarVariant::Cifar10, i));
    }
    std::fs::write(dir.join("data_batch_1.bin"), &bytes)?;
    bytes.clear();
    for i in train..train + test {
        bytes.extend_from_slice(&cifar::fixture_record(CifarVariant::Cifar10, i));
    }
    std::fs::write(dir.join("test_batch.bin"), &bytes)?;
    Ok(())
}

/// One end-to-end single-device training arm (the native preset), with
/// the input pipeline serial or prefetched. Returns (wall s, steps, params).
fn train_at(threads: usize, prefetch: bool) -> Result<(f64, usize, ParamSet)> {
    let mut cfg = preset("native")?;
    cfg.apply_kv("threads", &threads.to_string())?;
    let lab = Lab::new(cfg)?;
    let mut env = lab.env();
    env.prefetch = prefetch; // explicit: immune to SWAP_PREFETCH overrides
    let arm = BaselineConfig {
        devices: 1,
        epochs: 3,
        sched: Schedule::Constant(0.05),
        stop_train_acc: 1.1,
        seed: lab.cfg.seed,
    };
    let (secs, r) = time_once(|| run_baseline(&env, &arm));
    let r = r?;
    Ok((secs, r.progress.steps, r.params))
}

fn main() -> Result<()> {
    let threads = parallel::default_threads().max(2);

    // ---- assembly throughput (counter-keyed augmentation) --------------
    let gen = Generator::new(SynthSpec::for_preset(10, 32, 1));
    let ds = gen.sample(256, 10);
    let idx: Vec<usize> = (0..64).collect();
    let aug = AugStream { seed: 0, stream: 0 };
    let mut batcher = Batcher::new(64, 32, AugmentSpec::cifar_default());
    let mut hb = batcher.make_batch();
    let mut step = 0u64;
    let s_aug = bench(3, 30, || {
        batcher.assemble_step_into(&ds, &idx, aug, step, 0, &mut hb);
        step += 1;
    });
    let clean = Batcher::new(64, 32, AugmentSpec::none());
    let s_clean = bench(3, 30, || {
        clean.assemble_clean_into(&ds, &idx, &mut hb);
    });
    let aug_ips = 64.0 / s_aug.mean;
    let clean_ips = 64.0 / s_clean.mean;
    println!("assembly: augmented {aug_ips:.0} img/s | clean {clean_ips:.0} img/s");

    // ---- synth vs disk source ------------------------------------------
    let (synth_secs, synth_ds) = time_once(|| gen.sample(512, 10));
    let dir = std::env::temp_dir().join(format!("swap-bench-cifar-{}", std::process::id()));
    write_cifar_dir(&dir, 512, 64)?;
    let source = CifarSource::new(CifarVariant::Cifar10, &dir, 512, 64);
    let (disk_secs, loaded) = time_once(|| source.load());
    let (disk_train, _) = loaded?;
    assert_eq!(disk_train.n, synth_ds.n);
    let synth_ips = 512.0 / synth_secs;
    let disk_ips = 512.0 / disk_secs;
    println!("sources (512 imgs): synth {synth_ips:.0} img/s | disk {disk_ips:.0} img/s");
    std::fs::remove_dir_all(&dir).ok();

    // ---- pipeline overlap capacity (input-bound regime) ----------------
    // produce = real augmented assembly of a 256-image batch; consume = a
    // cheap device step (tiny-model forward on 8 of the produced rows).
    // When assembly cost rivals compute, the prefetched pipeline must run
    // at ~max(produce, consume) instead of their sum.
    let engine = NativeBackend::new(NativeSpec::new("bench", 4, 10, 16).with_batches(&[8]))?;
    let m = engine.manifest().clone();
    let pgen = Generator::new(SynthSpec::for_preset(10, 16, 2));
    let pds = pgen.sample(512, 10);
    let params = ParamSet::init(&m, 0);
    let pidx: Vec<usize> = (0..256).collect();
    let pix = pds.pixels_per_image();
    const MICRO_STEPS: usize = 40;
    let (pds_ref, pidx_ref) = (&pds, &pidx);
    let mut run_micro = |overlap: bool| -> Result<(f64, u64)> {
        let mut pb = Batcher::new(256, 16, AugmentSpec::cifar_default());
        let slots: Vec<HostBatch> = (0..2).map(|_| pb.make_batch()).collect();
        let mut sub = HostBatch {
            images: vec![0.0; 8 * pix],
            labels: vec![0; 8],
            batch: 8,
            image_size: 16,
        };
        let mut checksum = 0u64;
        let produce = move |s: usize, out: &mut HostBatch| {
            pb.assemble_step_into(pds_ref, pidx_ref, aug, s as u64, 0, out);
        };
        let (secs, out) = time_once(|| {
            prefetch::run_pipeline(MICRO_STEPS, slots, overlap, produce, |_, out| {
                sub.images.copy_from_slice(&out.images[..8 * pix]);
                sub.labels.copy_from_slice(&out.labels[..8]);
                let moments = engine.bn_moments(params.as_slice(), &sub)?;
                checksum = checksum
                    .wrapping_add(moments.iter().map(|x| x.to_bits() as u64).sum::<u64>())
                    .wrapping_add(out.labels.iter().map(|&l| l as u64).sum::<u64>());
                Ok(true)
            })
        });
        out?;
        Ok((secs, checksum))
    };
    let (micro_serial, sum_serial) = run_micro(false)?;
    let (micro_pre, sum_pre) = run_micro(true)?;
    assert_eq!(
        sum_serial, sum_pre,
        "prefetched pipeline must consume bitwise-identical batches"
    );
    let micro_serial_ms = micro_serial * 1e3 / MICRO_STEPS as f64;
    let micro_pre_ms = micro_pre * 1e3 / MICRO_STEPS as f64;
    println!(
        "pipeline micro (B=256 assembly + B=8 forward): serial {micro_serial_ms:.3} ms/step \
         | prefetched {micro_pre_ms:.3} ms/step | speedup {:.2}x",
        micro_serial_ms / micro_pre_ms
    );

    // ---- end-to-end training (native preset, devices=1) ----------------
    let (train_serial, steps, p_serial) = train_at(threads, false)?;
    let (train_pre, steps_b, p_pre) = train_at(threads, true)?;
    assert_eq!(steps, steps_b);
    let identical = p_serial == p_pre;
    assert!(identical, "prefetched training must be bitwise identical to serial assembly");
    let train_serial_ms = train_serial * 1e3 / steps as f64;
    let train_pre_ms = train_pre * 1e3 / steps as f64;
    println!(
        "train ({steps} steps, threads={threads}): serial {train_serial_ms:.3} ms/step | \
         prefetched {train_pre_ms:.3} ms/step | bitwise identical: {identical}"
    );

    let json = Json::obj(vec![
        ("bench", Json::Str("data_pipeline".to_string())),
        ("environment", env_manifest()),
        (
            "assembly",
            Json::obj(vec![
                ("batch", Json::Num(64.0)),
                ("image_size", Json::Num(32.0)),
                ("augmented_images_per_sec", Json::Num(aug_ips)),
                ("clean_images_per_sec", Json::Num(clean_ips)),
            ]),
        ),
        (
            "sources",
            Json::obj(vec![
                ("images", Json::Num(512.0)),
                ("synth_images_per_sec", Json::Num(synth_ips)),
                ("disk_images_per_sec", Json::Num(disk_ips)),
            ]),
        ),
        (
            "pipeline_micro",
            Json::obj(vec![
                ("steps", Json::Num(MICRO_STEPS as f64)),
                ("serial_step_ms", Json::Num(micro_serial_ms)),
                ("prefetched_step_ms", Json::Num(micro_pre_ms)),
                ("speedup", Json::Num(micro_serial_ms / micro_pre_ms)),
                ("bitwise_identical", Json::Bool(sum_serial == sum_pre)),
            ]),
        ),
        (
            "train",
            Json::obj(vec![
                ("steps", Json::Num(steps as f64)),
                ("threads", Json::Num(threads as f64)),
                ("serial_step_ms", Json::Num(train_serial_ms)),
                ("prefetched_step_ms", Json::Num(train_pre_ms)),
                ("speedup", Json::Num(train_serial_ms / train_pre_ms)),
                ("bitwise_identical", Json::Bool(identical)),
            ]),
        ),
    ])
    .to_string_pretty();
    std::fs::write("BENCH_data.json", &json)?;
    std::fs::create_dir_all("results")?;
    std::fs::write("results/BENCH_data.json", &json)?;
    println!("wrote BENCH_data.json");
    Ok(())
}
