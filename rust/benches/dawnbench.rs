//! Bench: the §5.1 DAWNBench claim — time-to-target accuracy for a fast
//! SWAP configuration vs the small-batch baseline (paper: 27s vs 37s on
//! CIFAR10-94%, a 0.73x ratio). Here the target is 95% of what the SB
//! baseline reaches; shape criterion: fast-SWAP hits the target in well
//! under the SB time.
//! Run: cargo bench --bench dawnbench

use swap::experiments::{tables, Lab};

fn main() -> swap::util::Result<()> {
    let lab = Lab::new(swap::config::preset("cifar10sim")?)?;
    let t = tables::dawnbench(&lab, 0.95)?;
    t.print();
    tables::save_table(&t, "dawnbench")?;
    Ok(())
}
