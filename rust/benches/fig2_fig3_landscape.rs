//! Bench: regenerate Figures 2 and 3 — train/test error surfaces over the
//! plane through (LB, worker, SWAP) and the plane through three workers.
//! Writes results/fig{2,3}_surface.csv + anchor files. Shape criteria:
//! workers sit on different sides of the train-error basin, SWAP interior
//! with lower test error.
//! Run: cargo bench --bench fig2_fig3_landscape

use swap::experiments::{figures, Lab};
use swap::landscape::GridSpec;

fn main() -> swap::util::Result<()> {
    let mut cfg = swap::config::preset("cifar10sim")?;
    // landscape runs are eval-heavy; a lighter config keeps this bench fast
    cfg.apply_kv("n_train", "512")?;
    cfg.apply_kv("n_test", "256")?;
    cfg.apply_kv("workers", "4")?;
    cfg.apply_kv("lb_devices", "4")?;
    cfg.apply_kv("phase1_max_epochs", "16")?;
    cfg.apply_kv("sb_epochs", "12")?;
    cfg.apply_kv("phase2_epochs", "4")?;
    let lab = Lab::new(cfg)?;
    let grid = GridSpec { n: 11, margin: 0.3, max_eval_batches: 3 };
    let figs = figures::fig2_fig3(&lab, &grid)?;

    // Fig 2: SWAP anchor should have the lowest test error of the anchors
    for (name, a, b) in &figs.fig2_anchors {
        let p = figs.fig2.nearest(*a, *b);
        println!("fig2 {name}: train_err {:.3} test_err {:.3}", p.train_err, p.test_err);
    }
    for (name, a, b) in &figs.fig3_anchors {
        let p = figs.fig3.nearest(*a, *b);
        println!("fig3 {name}: train_err {:.3} test_err {:.3}", p.train_err, p.test_err);
    }
    println!("best test err on fig3 plane: {:.4}", figs.fig3.best_test.test_err);
    Ok(())
}
