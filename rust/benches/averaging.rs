//! Averaging-policy ablation bench: the four pluggable policies (uniform /
//! swa / hierarchical / adaptive) head to head — micro-level streaming
//! overhead against the legacy terminal `ParamSet::average_mt`, and
//! end-to-end SWAP runs on the tiny native backend with per-policy test
//! accuracy and modeled time-to-result. Asserts along the way that the
//! Uniform policy is BITWISE-identical to the legacy mean at threads 1
//! and 4 (the refactor's acceptance criterion). Emits
//! `BENCH_averaging.json` (and a copy under results/).
//! Run: cargo bench --bench averaging

use swap::bench::{bench, env_manifest, Stats, Table};
use swap::coordinator::{
    parallel, run_swap, AveragingPolicy, AveragingSpec, Candidate, CandidateKind, SwapConfig,
    TrainEnv,
};
use swap::data::{AugmentSpec, Generator, SynthSpec};
use swap::model::ParamSet;
use swap::optim::Schedule;
use swap::runtime::native::{native_manifest, NativeSpec};
use swap::runtime::{Backend, NativeBackend};
use swap::sim::{CostModel, DeviceModel, NetModel};
use swap::util::{Json, Result};

const W: usize = 8;

fn observe_all(policy: &mut dyn AveragingPolicy, sets: &[ParamSet], threads: usize) {
    for (k, s) in sets.iter().enumerate() {
        policy
            .observe(
                s,
                Candidate { kind: CandidateKind::Worker(k), val_acc: Some(0.5) },
                threads,
            )
            .unwrap();
    }
}

struct MicroRow {
    policy: String,
    threads: usize,
    stats: Stats,
}

struct AblationRow {
    policy: String,
    test_acc1: f64,
    before_avg_acc1: f64,
    modeled_seconds: f64,
    contributing: usize,
}

fn main() -> Result<()> {
    let threads = parallel::default_threads().max(2);

    // ---- micro: streaming-policy overhead vs the legacy terminal mean ----
    let m = native_manifest(&NativeSpec::new("averaging", 16, 10, 32));
    let models: Vec<ParamSet> = (0..W).map(|w| ParamSet::init(&m, w as u64)).collect();
    println!("averaging bench: {} params, W={W}, threads={threads}", m.num_params);

    let mut micro: Vec<MicroRow> = Vec::new();
    let legacy_seq = bench(2, 20, || {
        ParamSet::average_mt(&models, 1).unwrap();
    });
    micro.push(MicroRow { policy: "legacy_average_mt".into(), threads: 1, stats: legacy_seq });
    let legacy_par = bench(2, 20, || {
        ParamSet::average_mt(&models, threads).unwrap();
    });
    micro.push(MicroRow { policy: "legacy_average_mt".into(), threads, stats: legacy_par });

    let specs = [
        AveragingSpec::Uniform,
        AveragingSpec::Swa,
        AveragingSpec::Hierarchical { groups: 2 },
        AveragingSpec::Adaptive { window: 4, min_improve: 1.0 },
    ];
    for spec in &specs {
        for t in [1usize, threads] {
            let stats = bench(2, 20, || {
                let mut pol = spec.build();
                observe_all(pol.as_mut(), &models, t);
                pol.average(t).unwrap();
            });
            micro.push(MicroRow { policy: spec.id(), threads: t, stats });
        }
    }

    // the acceptance parity, in-bench: Uniform streams to EXACTLY the bits
    // the legacy terminal mean produces, sequential and chunk-parallel
    for t in [1usize, 4] {
        let legacy = ParamSet::average_mt(&models, t).unwrap();
        let mut pol = AveragingSpec::Uniform.build();
        observe_all(pol.as_mut(), &models, t);
        assert_eq!(
            pol.average(t).unwrap(),
            legacy,
            "uniform policy parity vs legacy average_mt (threads={t})"
        );
    }
    println!("parity: uniform == legacy average_mt bitwise at threads 1 and 4");

    // ---- end-to-end: SWAP under each policy on the tiny backend ----------
    let engine = NativeBackend::tiny();
    let mf = engine.manifest().clone();
    let gen = Generator::new(SynthSpec::for_preset(mf.model.num_classes, mf.model.image_size, 99));
    let train = gen.sample(96, 10);
    let test = gen.sample(32, 11);
    let val = gen.sample(24, 12); // held-out split for the adaptive gate
    let cost = CostModel::new(DeviceModel::v100_like(), NetModel::pcie_like(), &mf);
    let env = TrainEnv {
        engine: &engine,
        cost: &cost,
        train: &train,
        test: &test,
        val: Some(&val),
        augment: AugmentSpec::none(),
        exec_batch: 8,
        bn_batches: 2,
        threads,
        prefetch: false,
    };
    let swap_cfg = |averaging: AveragingSpec| SwapConfig {
        workers: 4,
        group_devices: 1,
        phase1_max_epochs: 2,
        phase1_stop_acc: 1.1,
        phase1_sched: Schedule::Constant(0.08),
        phase2_epochs: 2,
        phase2_sched: Schedule::Constant(0.02),
        seed: 7,
        averaging,
        snapshot_every: None,
        phase1_snapshot_every: None,
        phase1_dist: false,
        phase1_record_every: 1,
    };
    let mut ablation: Vec<AblationRow> = Vec::new();
    for spec in &specs {
        let r = run_swap(&env, &swap_cfg(spec.clone()))?;
        if *spec == AveragingSpec::Uniform {
            let legacy = ParamSet::average_mt(&r.worker_params, threads)?;
            assert_eq!(
                r.final_params, legacy,
                "uniform SWAP phase 3 must remain bitwise the legacy mean"
            );
        }
        let contributing = r
            .averaging_state
            .get("contributing")
            .and_then(|v| v.as_usize())
            .unwrap_or(0);
        ablation.push(AblationRow {
            policy: spec.id(),
            test_acc1: r.final_stats.accuracy1(),
            before_avg_acc1: r.before_avg_acc1(),
            modeled_seconds: r.clock.seconds,
            contributing,
        });
    }

    // ---- report ----------------------------------------------------------
    let mut tm = Table::new(
        &format!("averaging policies — streaming overhead ({} params, W={W})", m.num_params),
        &["policy", "threads", "mean (ms)", "std (ms)", "min (ms)"],
    );
    for r in &micro {
        tm.row(&[
            r.policy.clone(),
            r.threads.to_string(),
            format!("{:.3}", r.stats.mean * 1e3),
            format!("{:.3}", r.stats.std * 1e3),
            format!("{:.3}", r.stats.min * 1e3),
        ]);
    }
    tm.print();

    let mut ta = Table::new(
        "averaging policies — SWAP end-to-end (tiny backend, W=4)",
        &["policy", "before avg (%)", "after avg (%)", "modeled time (s)", "contributing"],
    );
    for r in &ablation {
        ta.row(&[
            r.policy.clone(),
            format!("{:.2}", r.before_avg_acc1 * 100.0),
            format!("{:.2}", r.test_acc1 * 100.0),
            format!("{:.3}", r.modeled_seconds),
            r.contributing.to_string(),
        ]);
    }
    ta.print();

    let micro_rows: Vec<Json> = micro
        .iter()
        .map(|r| {
            Json::obj(vec![
                ("policy", Json::Str(r.policy.clone())),
                ("threads", Json::Num(r.threads as f64)),
                ("mean_seconds", Json::Num(r.stats.mean)),
                ("std_seconds", Json::Num(r.stats.std)),
                ("min_seconds", Json::Num(r.stats.min)),
            ])
        })
        .collect();
    let ablation_rows: Vec<Json> = ablation
        .iter()
        .map(|r| {
            Json::obj(vec![
                ("policy", Json::Str(r.policy.clone())),
                ("test_acc1", Json::Num(r.test_acc1)),
                ("before_avg_acc1", Json::Num(r.before_avg_acc1)),
                ("modeled_seconds", Json::Num(r.modeled_seconds)),
                ("contributing", Json::Num(r.contributing as f64)),
            ])
        })
        .collect();
    let json = Json::obj(vec![
        ("bench", Json::str("averaging")),
        ("environment", env_manifest()),
        ("num_params", Json::Num(m.num_params as f64)),
        ("workers", Json::Num(W as f64)),
        ("threads_parallel", Json::Num(threads as f64)),
        ("uniform_bitwise_vs_legacy", Json::Bool(true)),
        ("micro_rows", Json::Arr(micro_rows)),
        ("swap_ablation", Json::Arr(ablation_rows)),
    ])
    .to_string_pretty();
    std::fs::write("BENCH_averaging.json", &json)?;
    std::fs::create_dir_all("results")?;
    std::fs::write("results/BENCH_averaging.json", &json)?;
    println!("wrote BENCH_averaging.json");
    Ok(())
}
