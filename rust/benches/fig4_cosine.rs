//! Bench: regenerate Figure 4 — cosine similarity between the descent
//! direction −g_t and the direction toward the SWAP average, along a
//! phase-2 worker trajectory. Shape criterion: the cosine decays toward ~0
//! as training enters the late stage (progress becomes orthogonal to the
//! basin direction).
//! Run: cargo bench --bench fig4_cosine

use swap::experiments::{figures, Lab};

fn main() -> swap::util::Result<()> {
    let mut cfg = swap::config::preset("cifar10sim")?;
    cfg.apply_kv("n_train", "512")?;
    cfg.apply_kv("workers", "4")?;
    cfg.apply_kv("lb_devices", "4")?;
    cfg.apply_kv("phase1_max_epochs", "16")?;
    cfg.apply_kv("phase2_epochs", "6")?;
    let lab = Lab::new(cfg)?;
    let s = figures::fig4(&lab)?;
    let cos = s.column("cosine").unwrap();
    let steps = s.column("step").unwrap();
    for (t, c) in steps.iter().zip(&cos) {
        println!("step {t:>5}: cosine {c:+.4}");
    }
    let early: f64 = cos.iter().take(3).sum::<f64>() / 3.0_f64.min(cos.len() as f64);
    let late: f64 = cos.iter().rev().take(3).sum::<f64>() / 3.0_f64.min(cos.len() as f64);
    println!("early mean {early:.4} -> late mean {late:.4} (paper: decays)");
    Ok(())
}
