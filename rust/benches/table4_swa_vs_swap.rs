//! Bench: regenerate the paper's Table 4 (SWA vs SWAP on CIFAR100).
//! Five arms: LB-SWA, LB→SB-SWA, SB-SWA, SWAP (short), SWAP (long).
//! Shape criteria: SB-SWA reaches the best accuracy but at many-x the
//! time; LB-SWA fails to improve; long-phase-2 SWAP ≈ SB-SWA accuracy at
//! a fraction of the time (paper: 3.5x less).
//! Run: cargo bench --bench table4_swa_vs_swap

use swap::experiments::{tables, Lab};

fn main() -> swap::util::Result<()> {
    let lab = Lab::new(swap::config::preset("cifar100sim")?)?;
    let t = tables::table4(&lab)?;
    t.print();
    tables::save_table(&t, "table4")?;
    Ok(())
}
