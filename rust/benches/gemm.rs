//! Blocked-GEMM smoke bench: GFLOP/s per ResNet9s conv shape (the paper's
//! width-64 CIFAR net), blocked-vs-reference at threads 1 and 4, the
//! scalar-vs-SIMD dispatch tiers, the fused im2col-packing conv path,
//! plus the int8 quantized GEMM tier on the same shapes. Emits
//! `BENCH_gemm.json` (and a copy under results/) — the compute baseline
//! of the perf trajectory, stamped with an environment manifest so
//! numbers are diffable across machines — and asserts blocked-vs-reference
//! (and every-tier-vs-scalar, f32 and int8 alike) BITWISE parity on
//! every shape along the way.
//! Run: cargo bench --bench gemm

use swap::bench::{env_manifest, time_once};
use swap::runtime::native::gemm::{conv3x3_into, matmul_into, matmul_into_tier, GemmScratch};
use swap::runtime::native::kernels::{im2col, matmul_reference};
use swap::runtime::native::model::{conv_layers, Dims};
use swap::runtime::native::qgemm::{qconv3x3_into, QuantScratch, QuantTensor};
use swap::util::simd::{self, Tier};
use swap::util::{Json, Result};

const BATCH: usize = 8;
const THREADS_PAR: usize = 4;

fn wave(n: usize, f: f32) -> Vec<f32> {
    (0..n).map(|i| (i as f32 * f + 0.2).sin() * 0.9).collect()
}

fn assert_bitwise(got: &[f32], want: &[f32], what: &str) {
    assert_eq!(got.len(), want.len(), "{what}: length");
    for (i, (g, w)) in got.iter().zip(want).enumerate() {
        assert_eq!(g.to_bits(), w.to_bits(), "{what}[{i}]: {g} vs {w}");
    }
}

/// Best-of-`runs` wall seconds for `f`.
fn best_of(runs: usize, mut f: impl FnMut()) -> f64 {
    let mut best = f64::INFINITY;
    for _ in 0..runs {
        let (s, ()) = time_once(&mut f);
        best = best.min(s);
    }
    best
}

fn main() -> Result<()> {
    // the paper's DAWNBench ResNet9s: width 64 on 32x32 images
    let d = Dims { width: 64, num_classes: 10, image_size: 32 };
    let mut scratch = GemmScratch::default();
    let mut rows = Vec::new();
    let active = simd::active();
    println!(
        "blocked GEMM vs reference, ResNet9s width {} image {} batch {BATCH} \
         (simd tier: {}):",
        d.width,
        d.image_size,
        active.name()
    );
    for (name, cin, cout, side) in conv_layers(&d) {
        let (m, k, n) = (BATCH * side * side, 9 * cin, cout);
        let gflop = 2.0 * (m * k * n) as f64 / 1e9;
        // the conv input image (for the fused-packing path) and its
        // materialized patch matrix (the reference operand)
        let x = wave(BATCH * side * side * cin, 0.37);
        let patches = im2col(&x, BATCH, side, side, cin, 1);
        let wts = wave(k * n, 0.73);

        // warmup (also the parity baseline), then the same best-of
        // harness as the blocked tier so the speedup is apples-to-apples
        let want = matmul_reference(&patches, &wts, m, k, n, 1);
        let want_tn = matmul_reference(&patches, &wts, m, k, n, THREADS_PAR);
        assert_bitwise(&want_tn, &want, &format!("{name}: reference t{THREADS_PAR} vs t1"));
        let ref_t1_s = best_of(2, || {
            matmul_reference(&patches, &wts, m, k, n, 1);
        });
        let ref_tn_s = best_of(2, || {
            matmul_reference(&patches, &wts, m, k, n, THREADS_PAR);
        });

        let mut out = vec![0.0f32; m * n];
        matmul_into(&mut out, &patches, &wts, m, k, n, 1, &mut scratch);
        assert_bitwise(&out, &want, &format!("{name}: blocked t1 vs reference"));
        let blk_t1_s = best_of(3, || {
            matmul_into(&mut out, &patches, &wts, m, k, n, 1, &mut scratch)
        });
        matmul_into(&mut out, &patches, &wts, m, k, n, THREADS_PAR, &mut scratch);
        assert_bitwise(&out, &want, &format!("{name}: blocked t{THREADS_PAR} vs reference"));
        let blk_tn_s = best_of(3, || {
            matmul_into(&mut out, &patches, &wts, m, k, n, THREADS_PAR, &mut scratch)
        });

        // dispatch tiers: pin every tier this host can run against the
        // scalar kernel bitwise, and time scalar vs the active tier — the
        // simd_speedup column is the headline of the SIMD micro-kernels
        let mut sout = vec![0.0f32; m * n];
        matmul_into_tier(&mut sout, &patches, &wts, m, k, n, 1, Tier::Scalar, &mut scratch);
        assert_bitwise(&sout, &want, &format!("{name}: scalar tier vs reference"));
        let scalar_t1_s = best_of(3, || {
            matmul_into_tier(&mut sout, &patches, &wts, m, k, n, 1, Tier::Scalar, &mut scratch)
        });
        for t in simd::tiers_available() {
            matmul_into_tier(&mut out, &patches, &wts, m, k, n, 1, t, &mut scratch);
            assert_bitwise(&out, &sout, &format!("{name}: tier {} vs scalar", t.name()));
        }
        let simd_t1_s = best_of(3, || {
            matmul_into_tier(&mut out, &patches, &wts, m, k, n, 1, active, &mut scratch)
        });

        // fused packing: conv straight from the NHWC image
        conv3x3_into(&mut out, &x, BATCH, side, side, cin, &wts, n, THREADS_PAR, &mut scratch);
        assert_bitwise(&out, &want, &format!("{name}: fused conv vs reference"));
        let fused_tn_s = best_of(3, || {
            conv3x3_into(&mut out, &x, BATCH, side, side, cin, &wts, n, THREADS_PAR, &mut scratch)
        });

        // int8 quantized tier on the same conv shape: weights pre-packed
        // once (as serving does at load), activations quantized per call.
        // Exact i32 accumulation makes every dispatch tier bitwise equal
        // to the quantized scalar kernel — assert it, then time the
        // active tier against the fused f32 conv at the same threads.
        let wq = QuantTensor::quantize(&wts, k, n);
        let mut qs = QuantScratch::default();
        let mut qwant = vec![0.0f32; m * n];
        qconv3x3_into(
            &mut qwant, &x, BATCH, side, side, cin, &wq, 1, Tier::Scalar, &mut qs,
        );
        let mut qout = vec![0.0f32; m * n];
        for t in simd::tiers_available() {
            qconv3x3_into(&mut qout, &x, BATCH, side, side, cin, &wq, 1, t, &mut qs);
            assert_bitwise(&qout, &qwant, &format!("{name}: int8 tier {} vs scalar", t.name()));
        }
        let q_tn_s = best_of(3, || {
            qconv3x3_into(
                &mut qout, &x, BATCH, side, side, cin, &wq, THREADS_PAR, active, &mut qs,
            )
        });

        let speedup_tn = ref_tn_s / blk_tn_s.max(1e-12);
        let int8_speedup_tn = fused_tn_s / q_tn_s.max(1e-12);
        let simd_speedup_t1 = scalar_t1_s / simd_t1_s.max(1e-12);
        println!(
            "  {name:<7} m={m:<6} k={k:<5} n={n:<4} | ref {:.2}/{:.2} GF/s | \
             blocked {:.2}/{:.2} GF/s | fused {:.2} GF/s | int8 {:.2} GF/s \
             ({int8_speedup_tn:.2}x) | speedup(t{THREADS_PAR}) {speedup_tn:.2}x \
             | {} {simd_speedup_t1:.2}x over scalar",
            gflop / ref_t1_s,
            gflop / ref_tn_s,
            gflop / blk_t1_s,
            gflop / blk_tn_s,
            gflop / fused_tn_s,
            gflop / q_tn_s,
            active.name(),
        );
        rows.push(Json::obj(vec![
            ("layer", Json::str(name)),
            ("m", Json::Num(m as f64)),
            ("k", Json::Num(k as f64)),
            ("n", Json::Num(n as f64)),
            ("gflop", Json::Num(gflop)),
            ("ref_t1_gflops", Json::Num(gflop / ref_t1_s)),
            ("ref_tn_gflops", Json::Num(gflop / ref_tn_s)),
            ("blocked_t1_gflops", Json::Num(gflop / blk_t1_s)),
            ("blocked_tn_gflops", Json::Num(gflop / blk_tn_s)),
            ("fused_conv_tn_gflops", Json::Num(gflop / fused_tn_s)),
            // int8 rows: effective GFLOP/s (same 2mkn op count), the
            // tier that ran, and its wall-time win over the f32 fused conv
            ("int8_tn_gflops", Json::Num(gflop / q_tn_s)),
            ("int8_tier", Json::str(active.name())),
            ("int8_speedup_tn", Json::Num(int8_speedup_tn)),
            ("scalar_t1_gflops", Json::Num(gflop / scalar_t1_s)),
            ("simd_tier", Json::str(active.name())),
            ("simd_t1_gflops", Json::Num(gflop / simd_t1_s)),
            ("simd_speedup_t1", Json::Num(simd_speedup_t1)),
            ("speedup_t1", Json::Num(ref_t1_s / blk_t1_s.max(1e-12))),
            ("speedup_tn", Json::Num(speedup_tn)),
            ("bitwise_identical", Json::Bool(true)), // asserted above
        ]));
    }

    let json = Json::obj(vec![
        ("bench", Json::str("gemm_microkernels")),
        ("batch", Json::Num(BATCH as f64)),
        ("width", Json::Num(d.width as f64)),
        ("image_size", Json::Num(d.image_size as f64)),
        ("threads_parallel", Json::Num(THREADS_PAR as f64)),
        ("environment", env_manifest()),
        ("rows", Json::Arr(rows)),
    ])
    .to_string_pretty();
    std::fs::write("BENCH_gemm.json", &json)?;
    std::fs::create_dir_all("results")?;
    std::fs::write("results/BENCH_gemm.json", &json)?;
    println!("wrote BENCH_gemm.json");
    Ok(())
}
