//! Transport wire bench — measured vs predicted phase-1 comm time.
//!
//! `ClusterClock` prices the distributed phase-1 collective with the α–β
//! [`NetModel::hub_exchange`] term. This bench closes ROADMAP item 1's
//! validation loop: it calibrates α (frame latency) and β (payload
//! bandwidth) on a real loopback socket pair, then times the actual
//! `serve_phase1` per-step wire pattern (broadcast + gradient gather over
//! real TCP) across member/device/payload combinations and holds the
//! measured wall clock against the model's prediction under the measured
//! constants. Emits `BENCH_transport.json` (and a copy under results/)
//! with one measured-vs-predicted row per combination, stamped with an
//! environment manifest.
//!
//! The α–β model ignores scheduler noise, syscall overhead beyond the
//! first frame, and kernel buffering, so agreement is asserted to a
//! deliberately loose factor-of-RATIO_TOL band — enough to catch a
//! mispriced topology (e.g. a ring term where a star belongs) without
//! flaking on a busy runner. rust/tests/transport.rs pins a wider band
//! in CI.
//! Run: cargo bench --bench transport

use swap::bench::env_manifest;
use swap::coordinator::transport::loopback::{calibrate, time_hub_exchange};
use swap::util::{Json, Result};

/// (members, group_devices, weight count) combinations: fan-out scaling
/// at a fixed payload, then payload scaling at a fixed fan-out.
const COMBOS: [(usize, usize, usize); 4] =
    [(2, 1, 1 << 14), (4, 1, 1 << 14), (2, 2, 1 << 14), (2, 1, 1 << 17)];

/// Steps to time per combination (plus one warm-up exchange inside).
const STEPS: usize = 12;

/// Accepted measured/predicted band. Loopback has no real wire, so the
/// α–β fit is coarse; a correct topology lands well inside [1/4, 4].
const RATIO_TOL: f64 = 4.0;

fn main() -> Result<()> {
    let cal = calibrate(64, 1 << 18)?;
    let net = cal.net_model();
    println!(
        "loopback calibration: latency {:.2} us | bandwidth {:.2} GiB/s",
        cal.latency * 1e6,
        cal.bandwidth / (1024.0 * 1024.0 * 1024.0)
    );

    let mut rows = Vec::new();
    println!("phase-1 hub exchange, measured vs predicted ({STEPS} steps each):");
    for (members, gd, numel) in COMBOS {
        let measured = time_hub_exchange(members, gd, numel, STEPS)?;
        let bytes = 4 * numel as u64;
        let predicted = net.hub_exchange(bytes, members, members * gd);
        let ratio = measured / predicted.max(1e-12);
        println!(
            "  members {members} x gd {gd} | {:>8} B | measured {:>9.1} us | \
             predicted {:>9.1} us | ratio {ratio:.2}",
            bytes,
            measured * 1e6,
            predicted * 1e6
        );
        assert!(
            ratio > 1.0 / RATIO_TOL && ratio < RATIO_TOL,
            "hub_exchange model off by more than {RATIO_TOL}x: measured {measured:.3e}s \
             vs predicted {predicted:.3e}s (members {members}, gd {gd}, {bytes} B)"
        );
        rows.push(Json::obj(vec![
            ("members", Json::Num(members as f64)),
            ("group_devices", Json::Num(gd as f64)),
            ("payload_bytes", Json::Num(bytes as f64)),
            ("steps", Json::Num(STEPS as f64)),
            ("measured_per_step_s", Json::Num(measured)),
            ("predicted_per_step_s", Json::Num(predicted)),
            ("ratio", Json::Num(ratio)),
        ]));
    }

    let json = Json::obj(vec![
        ("bench", Json::str("transport_loopback")),
        (
            "calibration",
            Json::obj(vec![
                ("latency_s", Json::Num(cal.latency)),
                ("bandwidth_bytes_per_s", Json::Num(cal.bandwidth)),
            ]),
        ),
        ("ratio_tolerance", Json::Num(RATIO_TOL)),
        ("environment", env_manifest()),
        ("rows", Json::Arr(rows)),
    ])
    .to_string_pretty();
    std::fs::write("BENCH_transport.json", &json)?;
    std::fs::create_dir_all("results")?;
    std::fs::write("results/BENCH_transport.json", &json)?;
    println!("wrote BENCH_transport.json");
    Ok(())
}
