//! Microbenchmarks of the L3 hot paths (own harness; no criterion in the
//! vendored set): executable invocation, host SGD update, ring all-reduce,
//! weight averaging, batch assembly, literal conversion — plus
//! sequential-vs-parallel wall time for the kernel-threaded native engine.
//! These are the §Perf L3 numbers in EXPERIMENTS.md.
//! Run: cargo bench --bench microbench

use swap::bench::{bench, Table};
use swap::coordinator::{allreduce, parallel};
use swap::data::{AugStream, AugmentSpec, Batcher, Generator, SynthSpec};
use swap::model::ParamSet;
use swap::optim::{SgdConfig, SgdOptimizer};
use swap::runtime::{Backend, NativeBackend, NativeSpec};

fn main() -> swap::util::Result<()> {
    // the cifar10sim-shaped model on the native backend (swap for
    // Engine::load("artifacts/cifar10sim") + --features xla to bench PJRT)
    let engine =
        NativeBackend::new(NativeSpec::new("cifar10sim", 8, 10, 32).with_batches(&[64]))?;
    let threads = parallel::default_threads();
    let engine_mt = NativeBackend::new(
        NativeSpec::new("cifar10sim", 8, 10, 32)
            .with_batches(&[64])
            .with_threads(threads),
    )?;
    let m = engine.manifest().clone();
    let gen = Generator::new(SynthSpec::for_preset(m.model.num_classes, m.model.image_size, 1));
    let ds = gen.sample(256, 10);
    let aug = AugStream { seed: 0, stream: 0 };
    let mut batcher = Batcher::new(64, m.model.image_size, AugmentSpec::cifar_default());
    let idx: Vec<usize> = (0..64).collect();

    let mut t = Table::new(
        &format!("L3 microbenchmarks (cifar10sim, B=64, threads={threads})"),
        &["op", "mean (ms)", "std (ms)", "min (ms)"],
    );
    let mut row = |name: &str, s: swap::bench::Stats| {
        t.row(&[
            name.to_string(),
            format!("{:.3}", s.mean * 1e3),
            format!("{:.3}", s.std * 1e3),
            format!("{:.3}", s.min * 1e3),
        ]);
    };

    // batch assembly + counter-keyed augmentation into a reused HostBatch
    // (the zero-allocation hot-loop handoff)
    let mut reuse = batcher.make_batch();
    let mut asm_step = 0u64;
    let s = bench(3, 20, || {
        batcher.assemble_step_into(&ds, &idx, aug, asm_step, 0, &mut reuse);
        asm_step += 1;
    });
    row("batch assemble+augment (reused)", s);

    // fused train step (the phase-2 hot path), sequential vs parallel
    let mut params = ParamSet::init(&m, 0);
    let mut mom = params.zeros_like();
    let hb = batcher.assemble_step(&ds, &idx, aug, 1000, 0);
    let s = bench(2, 10, || {
        engine
            .train_step(params.as_mut_slice(), mom.as_mut_slice(), &hb, 0.01)
            .unwrap();
    });
    row("fused train step (threads=1)", s);
    let s = bench(2, 10, || {
        engine_mt
            .train_step(params.as_mut_slice(), mom.as_mut_slice(), &hb, 0.01)
            .unwrap();
    });
    row(&format!("fused train step (threads={threads})"), s);

    // gradient step (phase-1 per-worker call), sequential vs parallel
    let s = bench(2, 10, || {
        engine.grad(params.as_slice(), &hb).unwrap();
    });
    row("grad step (threads=1)", s);
    let s = bench(2, 10, || {
        engine_mt.grad(params.as_slice(), &hb).unwrap();
    });
    row(&format!("grad step (threads={threads})"), s);

    // host SGD update over all tensors
    let g = engine.grad(params.as_slice(), &hb)?;
    let mut opt = SgdOptimizer::new(SgdConfig { momentum: 0.9, weight_decay: 5e-4 }, &params);
    let s = bench(3, 50, || {
        opt.step(&mut params, &g.grads, 0.01).unwrap();
    });
    row("host SGD-Nesterov update", s);

    // ring all-reduce of 8 worker gradient arenas, fully in place. Each
    // run reduces the previous run's buffers — values grow but the
    // arithmetic (and its wall time) is value-independent, so no reset
    // pollutes the timed region.
    let mut work: Vec<Vec<f32>> = (0..8).map(|_| g.grads.clone()).collect();
    let s = bench(3, 20, || {
        allreduce::ring_mean_inplace(&mut work).unwrap();
    });
    row("ring all-reduce in-place (W=8)", s);

    // phase-3 weight averaging of 8 models
    let models: Vec<ParamSet> = (0..8).map(|i| ParamSet::init(&m, i as u64)).collect();
    let s = bench(3, 50, || {
        ParamSet::average(&models).unwrap();
    });
    row("weight average (W=8)", s);

    // 8 independent grads on 1 thread vs the shared pool — the shape of
    // SWAP's phase-2 fan-out, without the training-loop bookkeeping
    let batches: Vec<_> = (0..8u64)
        .map(|w| batcher.assemble_step(&ds, &idx, aug, 2000, w * 64))
        .collect();
    let s = bench(1, 5, || {
        for hb in &batches {
            engine.grad(params.as_slice(), hb).unwrap();
        }
    });
    row("8 worker grads (sequential)", s);
    let s = bench(1, 5, || {
        let rs = parallel::parallel_map(threads, batches.iter().collect(), |_, hb| {
            engine.grad(params.as_slice(), hb)
        });
        for r in rs {
            r.unwrap();
        }
    });
    row(&format!("8 worker grads (threads={threads})"), s);

    t.print();
    std::fs::create_dir_all("results")?;
    std::fs::write("results/microbench.txt", t.render())?;
    std::fs::write("results/microbench.csv", t.to_csv())?;
    Ok(())
}
