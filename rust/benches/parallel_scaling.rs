//! Sequential-vs-parallel smoke bench: the same SWAP configuration (W=4
//! phase-2 workers) at `threads=1` and `threads=N`, end to end. Emits
//! `BENCH_parallel.json` (and a copy under results/) with both wall times
//! and verifies the acceptance property along the way: the two runs must
//! produce BITWISE-identical final parameters.
//! Run: cargo bench --bench parallel_scaling

use swap::bench::time_once;
use swap::config::preset;
use swap::coordinator::{parallel, run_swap};
use swap::experiments::Lab;
use swap::util::{Json, Result};

fn run_at(threads: usize) -> Result<(f64, swap::coordinator::SwapResult)> {
    let mut cfg = preset("native")?;
    // a small but non-trivial SWAP arm: phase 2 dominates, W=4 workers
    cfg.apply_kv("workers", "4")?;
    cfg.apply_kv("lb_devices", "4")?;
    cfg.apply_kv("phase1_max_epochs", "1")?;
    cfg.apply_kv("phase1_stop_acc", "1.1")?;
    cfg.apply_kv("phase2_epochs", "2")?;
    cfg.apply_kv("threads", &threads.to_string())?;
    let lab = Lab::new(cfg)?;
    let (secs, r) = time_once(|| run_swap(&lab.env(), &lab.swap_arm(lab.cfg.seed)));
    Ok((secs, r?))
}

fn main() -> Result<()> {
    let threads = parallel::default_threads().max(2);
    let (seq_s, seq) = run_at(1)?;
    let (par_s, par) = run_at(threads)?;

    let identical = seq.final_params == par.final_params;
    let speedup = seq_s / par_s.max(1e-12);
    println!(
        "SWAP W=4: threads=1 {seq_s:.2}s | threads={threads} {par_s:.2}s | \
         speedup {speedup:.2}x | bitwise identical: {identical}"
    );
    assert!(
        identical,
        "threads={threads} must produce bitwise-identical final params"
    );

    let json = Json::obj(vec![
        ("bench", Json::Str("swap_parallel_scaling".to_string())),
        ("workers", Json::Num(4.0)),
        ("threads_sequential", Json::Num(1.0)),
        ("threads_parallel", Json::Num(threads as f64)),
        ("sequential_wall_seconds", Json::Num(seq_s)),
        ("parallel_wall_seconds", Json::Num(par_s)),
        ("speedup", Json::Num(speedup)),
        ("bitwise_identical", Json::Bool(identical)),
        ("final_acc_sequential", Json::Num(seq.final_stats.accuracy1())),
        ("final_acc_parallel", Json::Num(par.final_stats.accuracy1())),
    ])
    .to_string_pretty();
    std::fs::write("BENCH_parallel.json", &json)?;
    std::fs::create_dir_all("results")?;
    std::fs::write("results/BENCH_parallel.json", &json)?;
    println!("wrote BENCH_parallel.json");
    Ok(())
}
