//! Sequential-vs-parallel smoke bench: the same SWAP configuration (W=4
//! phase-2 workers) at `threads=1` and `threads=N`, end to end, plus a
//! dawnbench-shaped single-step row (fused train step on a width-16
//! ResNet9s over 32x32 images — the end-to-end step time the blocked
//! GEMM + workspace path is accountable for). Emits `BENCH_parallel.json`
//! (and a copy under results/) with all wall times and verifies the
//! acceptance property along the way: the two SWAP runs must produce
//! BITWISE-identical final parameters.
//! Run: cargo bench --bench parallel_scaling

use swap::bench::{env_manifest, time_once};
use swap::config::preset;
use swap::coordinator::{parallel, run_swap};
use swap::data::{AugStream, AugmentSpec, Batcher, Generator, SynthSpec};
use swap::experiments::Lab;
use swap::model::ParamSet;
use swap::runtime::{Backend, NativeBackend, NativeSpec};
use swap::util::{Json, Result};

fn run_at(threads: usize) -> Result<(f64, swap::coordinator::SwapResult)> {
    let mut cfg = preset("native")?;
    // a small but non-trivial SWAP arm: phase 2 dominates, W=4 workers
    cfg.apply_kv("workers", "4")?;
    cfg.apply_kv("lb_devices", "4")?;
    cfg.apply_kv("phase1_max_epochs", "1")?;
    cfg.apply_kv("phase1_stop_acc", "1.1")?;
    cfg.apply_kv("phase2_epochs", "2")?;
    cfg.apply_kv("threads", &threads.to_string())?;
    let lab = Lab::new(cfg)?;
    let (secs, r) = time_once(|| run_swap(&lab.env(), &lab.swap_arm(lab.cfg.seed)));
    Ok((secs, r?))
}

/// Best-of-3 fused train-step seconds on a dawnbench-shaped native model.
fn dawnbench_step(threads: usize) -> Result<(f64, f64)> {
    const WIDTH: usize = 16;
    const IMAGE: usize = 32;
    const BATCH: usize = 32;
    let engine = NativeBackend::new(
        NativeSpec::new("dawnbench", WIDTH, 10, IMAGE)
            .with_batches(&[BATCH])
            .with_threads(threads),
    )?;
    let m = engine.manifest().clone();
    let gen = Generator::new(SynthSpec::for_preset(10, IMAGE, 1));
    let ds = gen.sample(2 * BATCH, 10);
    let mut batcher = Batcher::new(BATCH, IMAGE, AugmentSpec::cifar_default());
    let idx: Vec<usize> = (0..BATCH).collect();
    let hb = batcher.assemble_step(&ds, &idx, AugStream { seed: 0, stream: 0 }, 0, 0);
    let mut params = ParamSet::init(&m, 0);
    let mut mom = params.zeros_like();
    // warmup builds the engine workspace + packed panels
    engine.train_step(params.as_mut_slice(), mom.as_mut_slice(), &hb, 0.01)?;
    let mut best = f64::INFINITY;
    for _ in 0..3 {
        let (s, r) = time_once(|| {
            engine.train_step(params.as_mut_slice(), mom.as_mut_slice(), &hb, 0.01)
        });
        r?;
        best = best.min(s);
    }
    // fwd+bwd ~ 3x forward FLOPs: the usual training-step accounting
    let gflop = 3.0 * m.flops_fwd_per_example as f64 * BATCH as f64 / 1e9;
    Ok((best, gflop / best))
}

fn main() -> Result<()> {
    let threads = parallel::default_threads().max(2);
    let (seq_s, seq) = run_at(1)?;
    let (par_s, par) = run_at(threads)?;

    let identical = seq.final_params == par.final_params;
    let speedup = seq_s / par_s.max(1e-12);
    println!(
        "SWAP W=4: threads=1 {seq_s:.2}s | threads={threads} {par_s:.2}s | \
         speedup {speedup:.2}x | bitwise identical: {identical}"
    );
    assert!(
        identical,
        "threads={threads} must produce bitwise-identical final params"
    );

    let (db_seq_s, db_seq_gflops) = dawnbench_step(1)?;
    let (db_par_s, db_par_gflops) = dawnbench_step(threads)?;
    println!(
        "dawnbench step (w16, 32x32, B=32): threads=1 {:.1}ms ({db_seq_gflops:.2} GF/s) | \
         threads={threads} {:.1}ms ({db_par_gflops:.2} GF/s)",
        db_seq_s * 1e3,
        db_par_s * 1e3,
    );

    let json = Json::obj(vec![
        ("bench", Json::Str("swap_parallel_scaling".to_string())),
        ("workers", Json::Num(4.0)),
        ("threads_sequential", Json::Num(1.0)),
        ("threads_parallel", Json::Num(threads as f64)),
        ("sequential_wall_seconds", Json::Num(seq_s)),
        ("parallel_wall_seconds", Json::Num(par_s)),
        ("speedup", Json::Num(speedup)),
        ("bitwise_identical", Json::Bool(identical)),
        ("final_acc_sequential", Json::Num(seq.final_stats.accuracy1())),
        ("final_acc_parallel", Json::Num(par.final_stats.accuracy1())),
        ("dawnbench_step_width", Json::Num(16.0)),
        ("dawnbench_step_batch", Json::Num(32.0)),
        ("dawnbench_step_threads1_seconds", Json::Num(db_seq_s)),
        ("dawnbench_step_threadsN_seconds", Json::Num(db_par_s)),
        ("dawnbench_step_threads1_gflops", Json::Num(db_seq_gflops)),
        ("dawnbench_step_threadsN_gflops", Json::Num(db_par_gflops)),
        (
            "dawnbench_step_speedup",
            Json::Num(db_seq_s / db_par_s.max(1e-12)),
        ),
        ("environment", env_manifest()),
    ])
    .to_string_pretty();
    std::fs::write("BENCH_parallel.json", &json)?;
    std::fs::create_dir_all("results")?;
    std::fs::write("results/BENCH_parallel.json", &json)?;
    println!("wrote BENCH_parallel.json");
    Ok(())
}
