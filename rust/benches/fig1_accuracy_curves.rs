//! Bench: regenerate Figure 1 — LR schedule + per-worker test accuracy
//! through both SWAP phases, plus the on-the-fly averaged-model accuracy
//! (which should dominate every individual worker during phase 2).
//! Writes results/fig1_lr.csv and results/fig1_accuracy.csv.
//! Run: cargo bench --bench fig1_accuracy_curves

use swap::experiments::{figures, Lab};

fn main() -> swap::util::Result<()> {
    // eval-heavy instrumentation: a lighter config keeps this bench fast
    let mut cfg = swap::config::preset("cifar10sim")?;
    cfg.apply_kv("n_train", "512")?;
    cfg.apply_kv("n_test", "256")?;
    cfg.apply_kv("workers", "4")?;
    cfg.apply_kv("lb_devices", "4")?;
    cfg.apply_kv("phase1_max_epochs", "20")?;
    cfg.apply_kv("phase2_epochs", "6")?;
    cfg.apply_kv("bn_batches", "4")?;
    let lab = Lab::new(cfg)?;
    let (lr, acc) = figures::fig1(&lab)?;
    println!("fig1: {} lr rows, {} accuracy rows", lr.len(), acc.len());
    // qualitative check: averaged model beats the mean worker at the end
    let avg_rows: Vec<f64> = acc
        .column("test_acc")
        .unwrap()
        .iter()
        .zip(acc.column("worker").unwrap())
        .filter(|(_, w)| *w == 99.0)
        .map(|(a, _)| *a)
        .collect();
    if let Some(last_avg) = avg_rows.last() {
        println!("final averaged-model accuracy on the curve: {last_avg:.4}");
    }
    Ok(())
}
