//! ImageNet(sim) — the §5.2 setting: piecewise LR + batch schedules, the
//! large-batch arm doubles both batch and LR (Figure 5), SWAP phase 2 runs
//! two *groups* of data-parallel workers (2 x 2 devices here, scaled from
//! the paper's 2 x 8 V100). Reports Top-1 AND Top-5 like Table 3.
//!
//!     cargo run --release --example imagenet_sim

use swap::config::preset;
use swap::coordinator::{run_baseline, run_swap};
use swap::experiments::Lab;
use swap::runtime::Backend;

fn main() -> swap::util::Result<()> {
    let lab = Lab::new(preset("imagenetsim")?)?;
    let env = lab.env();
    let seed = lab.cfg.seed;
    println!(
        "imagenetsim: {} classes, {} train images, piecewise schedule = {}",
        lab.engine.manifest().model.num_classes,
        lab.cfg.n_train,
        lab.cfg.imagenet_style
    );

    let sb = run_baseline(&env, &lab.sb_arm(seed))?;
    println!(
        "SB  (batch {:>4}): top1 {:.4} top5 {:.4} | modeled {:.2}s",
        lab.cfg.sb_devices * lab.cfg.exec_batch,
        sb.outcome.test_acc1,
        sb.outcome.test_acc5,
        sb.outcome.cluster_seconds
    );
    let lb = run_baseline(&env, &lab.lb_arm(seed))?;
    println!(
        "LB  (batch {:>4}): top1 {:.4} top5 {:.4} | modeled {:.2}s  (2x batch, 2x LR)",
        lab.cfg.lb_devices * lab.cfg.exec_batch,
        lb.outcome.test_acc1,
        lb.outcome.test_acc5,
        lb.outcome.cluster_seconds
    );
    let r = run_swap(&env, &lab.swap_arm(seed))?;
    println!(
        "SWAP ({}x{} devs): top1 {:.4} top5 {:.4} | modeled {:.2}s (before avg: {:.4}/{:.4})",
        lab.cfg.workers,
        lab.cfg.group_devices,
        r.final_stats.accuracy1(),
        r.final_stats.accuracy5(),
        r.clock.seconds,
        r.before_avg_acc1(),
        r.before_avg_acc5()
    );
    Ok(())
}
