//! End-to-end driver (DESIGN.md "end-to-end validation"): trains the
//! ResNet9s on the full cifar10sim workload through ALL layers of the
//! stack — rust coordinator -> PJRT runtime -> AOT HLO from JAX -> Pallas
//! kernel lineage — for several hundred optimizer steps, logging the loss
//! curve, then runs SWAP and compares all arms. Writes
//! results/e2e_loss_curve.csv. Takes a few minutes; the run recorded in
//! EXPERIMENTS.md used the default settings.
//!
//!     cargo run --release --example e2e_train

use swap::config::preset;
use swap::coordinator::{run_baseline, run_swap, run_sync_training, SyncTrainConfig, TrainEnv};
use swap::experiments::Lab;
use swap::metrics::SeriesLog;
use swap::model::ParamSet;
use swap::runtime::Backend;
use swap::sim::ClusterClock;

fn main() -> swap::util::Result<()> {
    let lab = Lab::new(preset("cifar10sim")?)?;
    let env: TrainEnv = lab.env();
    let m = lab.engine.manifest();
    println!(
        "e2e: resnet9s width={} ({} params), {} train / {} test synthetic images, B={}",
        m.model.width, m.num_params, lab.cfg.n_train, lab.cfg.n_test, lab.cfg.exec_batch
    );

    // ---- 1. plain training run with a logged loss curve ----------------
    let spe = lab.spe(1);
    let epochs = lab.cfg.sb_epochs;
    println!("training {} epochs = {} steps ...", epochs, epochs * spe);
    let mut params = ParamSet::init(m, lab.cfg.seed);
    let mut momentum = params.zeros_like();
    let mut clock = ClusterClock::new();
    let mut curve = SeriesLog::new(&["step", "lr", "batch_loss", "batch_acc"]);
    let sched = lab.cfg.sb_schedule(spe);
    let sched_for_log = sched.clone();
    run_sync_training(
        &env,
        &mut params,
        &mut momentum,
        &SyncTrainConfig {
            devices: 1,
            global_batch: lab.cfg.exec_batch,
            max_epochs: epochs,
            stop_train_acc: 1.1,
            sched,
            sched_offset: 0,
            seed_stream: 0,
            seed: lab.cfg.seed,
        },
        &mut clock,
        |step, _ps, stats| {
            curve.push(&[
                step as f64,
                sched_for_log.lr(step) as f64,
                stats.mean_loss(),
                stats.accuracy1(),
            ]);
        },
    )?;
    curve.write_csv("results/e2e_loss_curve.csv")?;
    let losses = curve.column("batch_loss").unwrap();
    let k = losses.len();
    println!(
        "loss curve: start {:.3} -> mid {:.3} -> end {:.3}  ({} points, results/e2e_loss_curve.csv)",
        losses[0],
        losses[k / 2],
        losses[k - 1],
        k
    );
    let stats = env.bn_and_eval(&params, lab.cfg.seed, &mut clock)?;
    println!("plain run test acc: {:.4}", stats.accuracy1());

    // ---- 2. the three paper arms on the same workload -------------------
    let sb = run_baseline(&env, &lab.sb_arm(lab.cfg.seed))?;
    let lb = run_baseline(&env, &lab.lb_arm(lab.cfg.seed))?;
    let swap = run_swap(&env, &lab.swap_arm(lab.cfg.seed))?;
    println!("\n=== e2e summary (modeled cluster time) ===");
    println!("SB   : acc {:.4} @ {:>7.2}s", sb.outcome.test_acc1, sb.outcome.cluster_seconds);
    println!("LB   : acc {:.4} @ {:>7.2}s", lb.outcome.test_acc1, lb.outcome.cluster_seconds);
    println!(
        "SWAP : acc {:.4} @ {:>7.2}s (before avg {:.4}; phase1 τ-exit at {:.1} epochs)",
        swap.final_stats.accuracy1(),
        swap.clock.seconds,
        swap.before_avg_acc1(),
        swap.phase1.epochs
    );
    let ok = swap.final_stats.accuracy1() >= swap.before_avg_acc1()
        && swap.clock.seconds < sb.outcome.cluster_seconds;
    println!("shape holds (avg helps && SWAP faster than SB): {ok}");
    Ok(())
}
