//! Quickstart: the smallest end-to-end SWAP run.
//!
//! Loads the tiny preset artifacts (built by `make artifacts`), generates a
//! synthetic dataset, runs the three-phase SWAP algorithm with 2 workers,
//! and prints accuracies before/after weight averaging plus the modeled
//! cluster time. Runs in well under a minute.
//!
//!     cargo run --release --example quickstart

use swap::config::preset;
use swap::coordinator::run_swap;
use swap::experiments::Lab;

fn main() -> swap::util::Result<()> {
    // 1. a Lab bundles artifacts (engine), synthetic data, and cost model
    let lab = Lab::new(preset("tiny")?)?;

    // 2. the SWAP arm derived from the preset (workers, schedules, τ)
    let cfg = lab.swap_arm(lab.cfg.seed);
    println!(
        "SWAP on '{}': {} workers x {} device(s), phase1 ≤{} epochs (τ={}), phase2 {} epochs",
        lab.cfg.preset,
        cfg.workers,
        cfg.group_devices,
        cfg.phase1_max_epochs,
        cfg.phase1_stop_acc,
        cfg.phase2_epochs
    );

    // 3. run all three phases
    let r = run_swap(&lab.env(), &cfg)?;

    println!(
        "phase 1: {:.1} epochs, train acc {:.3}, modeled {:.3}s",
        r.phase1.epochs, r.phase1.train_acc, r.phase1_seconds
    );
    for (w, stats) in r.worker_stats.iter().enumerate() {
        println!("worker {w}: test acc {:.4} (before averaging)", stats.accuracy1());
    }
    println!(
        "averaged model: test acc {:.4} | total modeled {:.3}s (compute {:.3}s, comm {:.3}s)",
        r.final_stats.accuracy1(),
        r.clock.seconds,
        r.clock.compute,
        r.clock.comm
    );
    println!(
        "divergence between workers: {:.3} (L2 in weight space)",
        r.worker_params[0].distance(&r.worker_params[1])?
    );
    Ok(())
}
