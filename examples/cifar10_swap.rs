//! CIFAR10(sim) — a single Table-1-style comparison at full preset scale:
//! small-batch SGD vs large-batch SGD vs SWAP, one seed each, with the
//! virtual-cluster time breakdown the paper's Table 1 reports.
//!
//!     cargo run --release --example cifar10_swap
//!
//! (Use `cargo bench --bench table1_cifar10` for the multi-run version
//! with mean ± std statistics.)

use swap::config::preset;
use swap::coordinator::{run_baseline, run_swap};
use swap::experiments::Lab;

fn main() -> swap::util::Result<()> {
    let lab = Lab::new(preset("cifar10sim")?)?;
    let env = lab.env();
    let seed = lab.cfg.seed;

    println!("== small-batch SGD (1 device, B={}) ==", lab.cfg.exec_batch);
    let sb = run_baseline(&env, &lab.sb_arm(seed))?;
    println!(
        "  acc {:.4} | modeled {:.2}s | {:.0} epochs",
        sb.outcome.test_acc1, sb.outcome.cluster_seconds, sb.progress.epochs
    );

    println!(
        "== large-batch SGD ({} devices, B={}) ==",
        lab.cfg.lb_devices,
        lab.cfg.lb_devices * lab.cfg.exec_batch
    );
    let lb = run_baseline(&env, &lab.lb_arm(seed))?;
    println!(
        "  acc {:.4} | modeled {:.2}s (comm {:.2}s) | {:.0} epochs",
        lb.outcome.test_acc1,
        lb.outcome.cluster_seconds,
        lb.clock.comm,
        lb.progress.epochs
    );

    println!("== SWAP ({} workers) ==", lab.cfg.workers);
    let r = run_swap(&env, &lab.swap_arm(seed))?;
    println!(
        "  phase 1 exits at train acc {:.3} after {:.1} epochs (τ = {})",
        r.phase1.train_acc, r.phase1.epochs, lab.cfg.phase1_stop_acc
    );
    println!(
        "  before averaging: mean worker acc {:.4} @ {:.2}s",
        r.before_avg_acc1(),
        r.phase2_seconds
    );
    println!(
        "  after averaging:  acc {:.4} @ {:.2}s",
        r.final_stats.accuracy1(),
        r.clock.seconds
    );

    println!("\nshape vs paper Table 1:");
    println!(
        "  time: SWAP {:.2}s vs LB {:.2}s vs SB {:.2}s (paper: 169 / 133 / 254)",
        r.clock.seconds, lb.outcome.cluster_seconds, sb.outcome.cluster_seconds
    );
    println!(
        "  acc:  SWAP {:.4} vs LB {:.4} vs SB {:.4} (paper: 95.23 / 94.77 / 95.24)",
        r.final_stats.accuracy1(),
        lb.outcome.test_acc1,
        sb.outcome.test_acc1
    );
    Ok(())
}
