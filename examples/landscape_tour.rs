//! Landscape tour — the §4 visualization machinery on a small setting:
//! run SWAP with 3 workers, build the two planes of Figures 2 and 3,
//! evaluate a coarse error grid, and print an ASCII rendering of the
//! train-error basin with the anchor points overlaid.
//!
//!     cargo run --release --example landscape_tour

use swap::config::preset;
use swap::coordinator::run_swap;
use swap::experiments::Lab;
use swap::landscape::{eval_grid, GridSpec, Plane};
use swap::sim::ClusterClock;

fn main() -> swap::util::Result<()> {
    let mut cfg = preset("cifar10sim")?;
    cfg.apply_kv("n_train", "512")?;
    cfg.apply_kv("n_test", "256")?;
    cfg.apply_kv("workers", "3")?;
    cfg.apply_kv("lb_devices", "3")?;
    cfg.apply_kv("phase1_max_epochs", "12")?;
    cfg.apply_kv("phase2_epochs", "4")?;
    let lab = Lab::new(cfg)?;
    let env = lab.env();

    let r = run_swap(&env, &lab.swap_arm(lab.cfg.seed))?;
    let plane = Plane::through(&r.worker_params[0], &r.worker_params[1], &r.worker_params[2])?;
    let swap_xy = plane.project(&r.final_params)?;
    println!(
        "plane through 3 workers; SWAP projects to ({:.2},{:.2}), residual {:.3}",
        swap_xy.0,
        swap_xy.1,
        plane.residual(&r.final_params)?
    );

    let spec = GridSpec { n: 9, margin: 0.4, max_eval_batches: 2 };
    let mut clock = ClusterClock::new();
    let grid = eval_grid(&env, &plane, &spec, lab.cfg.seed, &mut clock)?;

    // ASCII heat map of train error (darker = higher error)
    let shades = [' ', '.', ':', '-', '=', '+', '*', '#', '%', '@'];
    let (lo, hi) = grid.points.iter().fold((1.0f64, 0.0f64), |(lo, hi), p| {
        (lo.min(p.train_err), hi.max(p.train_err))
    });
    println!("train error over the plane (lo {lo:.3} hi {hi:.3}):");
    for j in (0..spec.n).rev() {
        let mut line = String::new();
        for i in 0..spec.n {
            let p = grid.points[i * spec.n + j];
            let t = ((p.train_err - lo) / (hi - lo).max(1e-9) * 9.0) as usize;
            line.push(shades[t.min(9)]);
            line.push(' ');
        }
        println!("  {line}");
    }
    for (k, (a, b)) in plane.anchors.iter().enumerate() {
        let p = grid.nearest(*a, *b);
        println!("worker {k} @ ({a:.2},{b:.2}): train_err {:.3} test_err {:.3}", p.train_err, p.test_err);
    }
    let ps = grid.nearest(swap_xy.0, swap_xy.1);
    println!(
        "SWAP     @ ({:.2},{:.2}): train_err {:.3} test_err {:.3}  <- should be interior/lower",
        swap_xy.0, swap_xy.1, ps.train_err, ps.test_err
    );
    println!("BEST test err on plane: {:.3}", grid.best_test.test_err);
    Ok(())
}
