"""L1: fused SGD + Nesterov momentum + coupled weight decay, as Pallas.

This is the update rule of the paper (§5.1: Nesterov momentum 0.9, weight
decay 5e-4), fused into a single elementwise kernel so the phase-2 fused
train step (`train_b*` executables) performs parameter + momentum updates
in one pass over the weights — one HBM read and one HBM write per tensor,
instead of the 5+ passes an unfused implementation would make.

The learning rate is a *runtime* scalar input (a (1,) array broadcast to
every grid step via a constant index map) so a single AOT artifact serves
every LR schedule; momentum/weight-decay constants are compile-time baked
(they never change within a run).

Phase-1 of SWAP applies the *same* formula host-side in rust
(rust/src/optim/sgd.rs) between the gradient all-reduce and the next step;
`rust/tests/` asserts bit-level agreement between the two paths.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _ceil_to(x: int, m: int) -> int:
    return ((x + m - 1) // m) * m


def _sgd_kernel(lr_ref, p_ref, m_ref, g_ref, po_ref, mo_ref, *, mu, wd):
    lr = lr_ref[0]
    p = p_ref[...].astype(jnp.float32)
    m = m_ref[...].astype(jnp.float32)
    g = g_ref[...].astype(jnp.float32)
    g2 = g + wd * p
    m2 = mu * m + g2
    p2 = p - lr * (g2 + mu * m2)
    po_ref[...] = p2.astype(po_ref.dtype)
    mo_ref[...] = m2.astype(mo_ref.dtype)


def sgd_nesterov(p, m, g, lr, *, mu: float, wd: float, block: int = 1 << 16):
    """Fused Nesterov-SGD update on a flat (or any-shape) tensor.

    p, m, g: same shape/dtype; lr: () or (1,) f32 scalar array.
    Returns (p_new, m_new). Coupled weight decay: g' = g + wd*p.
    """
    shape, dtype = p.shape, p.dtype
    n = p.size
    bn = min(block, _ceil_to(max(n, 1), 8))
    npad = _ceil_to(n, bn)
    flat = [x.reshape(-1) for x in (p, m, g)]
    if npad != n:
        flat = [jnp.pad(x, (0, npad - n)) for x in flat]
    lr_arr = jnp.asarray(lr, jnp.float32).reshape(1)

    p2, m2 = pl.pallas_call(
        functools.partial(_sgd_kernel, mu=mu, wd=wd),
        grid=(npad // bn,),
        in_specs=[
            pl.BlockSpec((1,), lambda i: (0,)),  # lr broadcast to all steps
            pl.BlockSpec((bn,), lambda i: (i,)),
            pl.BlockSpec((bn,), lambda i: (i,)),
            pl.BlockSpec((bn,), lambda i: (i,)),
        ],
        out_specs=[
            pl.BlockSpec((bn,), lambda i: (i,)),
            pl.BlockSpec((bn,), lambda i: (i,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((npad,), dtype),
            jax.ShapeDtypeStruct((npad,), dtype),
        ],
        interpret=True,
    )(lr_arr, *flat)
    return p2[:n].reshape(shape), m2[:n].reshape(shape)
