"""L1 Pallas kernels for swap-train (build-time only; see DESIGN.md).

Every kernel has a pure-jnp oracle in `ref.py`; pytest + hypothesis assert
agreement across shapes and dtypes (python/tests/test_kernels.py).
"""

from .avg import weight_average
from .matmul import default_blocks, matmul_bias_act, vmem_bytes
from .sgd import sgd_nesterov
from .xent import cross_entropy

__all__ = [
    "cross_entropy",
    "default_blocks",
    "matmul_bias_act",
    "sgd_nesterov",
    "vmem_bytes",
    "weight_average",
]
