"""L1: phase-3 weight averaging as a Pallas kernel.

SWAP's final step (Algorithm 1, line 27) averages the W divergent worker
models: theta_hat = (1/W) sum_w theta_w. For multi-million-parameter models
this is a bandwidth-bound streaming reduction; the kernel reads one
(W, block) tile per grid step and emits the f32-accumulated mean, i.e. a
single pass over all W models' weights.

The rust coordinator also has a host-side implementation
(rust/src/model/average.rs) used when the weights already live on the host;
the two are cross-checked in the integration tests.
"""

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _ceil_to(x: int, m: int) -> int:
    return ((x + m - 1) // m) * m


def _avg_kernel(s_ref, o_ref):
    o_ref[...] = jnp.mean(s_ref[...].astype(jnp.float32), axis=0).astype(o_ref.dtype)


def weight_average(stacked, block: int = 1 << 16):
    """Mean over the leading (worker) axis. stacked: (W, N) -> (N,)."""
    w, n = stacked.shape
    bn = min(block, _ceil_to(max(n, 1), 8))
    npad = _ceil_to(n, bn)
    if npad != n:
        stacked = jnp.pad(stacked, ((0, 0), (0, npad - n)))
    out = pl.pallas_call(
        _avg_kernel,
        grid=(npad // bn,),
        in_specs=[pl.BlockSpec((w, bn), lambda i: (0, i))],
        out_specs=pl.BlockSpec((bn,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((npad,), stacked.dtype),
        interpret=True,
    )(stacked)
    return out[:n]
