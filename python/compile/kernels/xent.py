"""L1: fused softmax cross-entropy + top-1/top-5 accuracy, as Pallas.

One pass over the logits produces the summed batch loss and the number of
top-1 / top-5 correct predictions — the three statistics every executable
(grad/train/eval) reports to the rust coordinator. Summation (rather than
mean) makes multi-batch aggregation in rust exact: the coordinator divides
by the number of samples it actually fed.

Top-k via the *rank trick*: rank_i = |{c : logit[i,c] > logit[i,y_i]}|,
correct@k <=> rank_i < k. This is deterministic under ties, needs no sort,
and vectorizes to a single compare+reduce on the VPU.

The backward pass (softmax - onehot) is a custom VJP in plain jnp — it is
memory-bound and XLA fuses it completely, so a Pallas kernel would add
nothing on either backend.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _ceil_to(x: int, m: int) -> int:
    return ((x + m - 1) // m) * m


def _xent_kernel(logits_ref, labels_ref, loss_ref, c1_ref, c5_ref, *, nc: int):
    logits = logits_ref[...].astype(jnp.float32)  # (bb, Cpad)
    labels = labels_ref[...]                      # (bb,)
    bb = logits.shape[0]
    col = jax.lax.broadcasted_iota(jnp.int32, logits.shape, 1)
    valid = col < nc
    neg = jnp.float32(-1e30)
    logits = jnp.where(valid, logits, neg)
    # Padded rows carry label == -1 and contribute exactly zero below.
    row_valid = labels >= 0
    safe_labels = jnp.where(row_valid, labels, 0)

    mx = jnp.max(logits, axis=-1)
    lse = jnp.log(jnp.sum(jnp.exp(logits - mx[:, None]), axis=-1)) + mx
    onehot = (col == safe_labels[:, None]) & valid
    true_logit = jnp.sum(jnp.where(onehot, logits, 0.0), axis=-1)
    loss = jnp.where(row_valid, lse - true_logit, 0.0)
    rank = jnp.sum(((logits > true_logit[:, None]) & valid).astype(jnp.int32),
                   axis=-1)
    c1 = jnp.where(row_valid, (rank < 1).astype(jnp.int32), 0)
    c5 = jnp.where(row_valid, (rank < 5).astype(jnp.int32), 0)

    i = pl.program_id(0)

    @pl.when(i == 0)
    def _init():
        loss_ref[...] = jnp.zeros_like(loss_ref)
        c1_ref[...] = jnp.zeros_like(c1_ref)
        c5_ref[...] = jnp.zeros_like(c5_ref)

    loss_ref[0] += jnp.sum(loss)
    c1_ref[0] += jnp.sum(c1)
    c5_ref[0] += jnp.sum(c5)


def _xent_raw(logits, labels, block_b: int = 1024):
    b, nc = logits.shape
    bb = min(block_b, _ceil_to(b, 8))
    bp = _ceil_to(b, bb)
    ncp = _ceil_to(nc, 8)
    if (bp, ncp) != (b, nc):
        logits = jnp.pad(logits, ((0, bp - b), (0, ncp - nc)))
        labels = jnp.pad(labels, (0, bp - b), constant_values=-1)
    loss, c1, c5 = pl.pallas_call(
        functools.partial(_xent_kernel, nc=nc),
        grid=(bp // bb,),
        in_specs=[
            pl.BlockSpec((bb, ncp), lambda i: (i, 0)),
            pl.BlockSpec((bb,), lambda i: (i,)),
        ],
        out_specs=[
            pl.BlockSpec((1,), lambda i: (0,)),
            pl.BlockSpec((1,), lambda i: (0,)),
            pl.BlockSpec((1,), lambda i: (0,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((1,), jnp.float32),
            jax.ShapeDtypeStruct((1,), jnp.int32),
            jax.ShapeDtypeStruct((1,), jnp.int32),
        ],
        interpret=True,
    )(logits, labels)
    return loss[0], c1[0], c5[0]


@jax.custom_vjp
def cross_entropy(logits, labels):
    """(sum_loss f32, ncorrect1 i32, ncorrect5 i32) over the batch.

    logits: (B, C) float; labels: (B,) int32 in [0, C). Differentiable in
    logits (d sum_loss / d logits = softmax - onehot).
    """
    return _xent_raw(logits, labels)


def _xent_fwd(logits, labels):
    out = _xent_raw(logits, labels)
    return out, (logits, labels)


def _xent_bwd(res, cot):
    logits, labels = res
    dloss = cot[0]
    logits32 = logits.astype(jnp.float32)
    p = jnp.exp(logits32 - jnp.max(logits32, axis=-1, keepdims=True))
    p = p / jnp.sum(p, axis=-1, keepdims=True)
    onehot = jax.nn.one_hot(labels, logits.shape[-1], dtype=jnp.float32)
    dlogits = ((p - onehot) * dloss).astype(logits.dtype)
    return dlogits, None


cross_entropy.defvjp(_xent_fwd, _xent_bwd)
