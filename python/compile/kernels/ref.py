"""Pure-jnp reference oracles for every Pallas kernel (L1).

These are the CORE correctness signal: pytest (with hypothesis sweeps over
shapes and dtypes) asserts that each Pallas kernel in this package matches
its oracle to tight tolerances. The oracles are deliberately written in the
most obvious jnp style — no tiling, no tricks — so a reviewer can audit them
against the paper's equations directly.

SGD update follows the PyTorch/paper convention of *coupled* weight decay
with Nesterov momentum (momentum 0.9, wd 5e-4 in the paper, §5.1):

    g' = g + wd * p
    m' = mu * m + g'
    p' = p - lr * (g' + mu * m')
"""

import jax.numpy as jnp


def matmul_bias_act(a, b, bias=None, activation=None):
    """Reference for kernels.matmul.matmul_bias_act: act(a @ b + bias).

    a: (M, K), b: (K, N), bias: (N,) or None. Accumulates in f32 regardless
    of the input dtype (the MXU convention), returns the input dtype.
    """
    out = jnp.dot(a.astype(jnp.float32), b.astype(jnp.float32),
                  preferred_element_type=jnp.float32)
    if bias is not None:
        out = out + bias.astype(jnp.float32)[None, :]
    if activation == "relu":
        out = jnp.maximum(out, 0.0)
    elif activation not in (None, "none"):
        raise ValueError(f"unknown activation {activation!r}")
    return out.astype(a.dtype)


def sgd_nesterov(p, m, g, lr, *, mu, wd):
    """Reference for kernels.sgd.sgd_nesterov (coupled wd + Nesterov)."""
    p32, m32, g32 = (x.astype(jnp.float32) for x in (p, m, g))
    g2 = g32 + wd * p32
    m2 = mu * m32 + g2
    p2 = p32 - lr * (g2 + mu * m2)
    return p2.astype(p.dtype), m2.astype(m.dtype)


def cross_entropy(logits, labels):
    """Reference for kernels.xent.cross_entropy.

    Returns (sum_loss f32 scalar, ncorrect1 i32, ncorrect5 i32).
    Loss is the *sum* over the batch of softmax cross-entropy (the caller
    divides by the global batch size; summing makes multi-batch aggregation
    exact). Top-k correctness uses the rank of the true logit, i.e.
    rank_i = |{c : logits[i,c] > logits[i,y_i]}| and correct@k <=> rank < k,
    which is deterministic under ties.
    """
    logits = logits.astype(jnp.float32)
    mx = jnp.max(logits, axis=-1, keepdims=True)
    lse = jnp.log(jnp.sum(jnp.exp(logits - mx), axis=-1)) + mx[:, 0]
    true_logit = jnp.take_along_axis(logits, labels[:, None], axis=-1)[:, 0]
    loss = jnp.sum(lse - true_logit)
    rank = jnp.sum((logits > true_logit[:, None]).astype(jnp.int32), axis=-1)
    ncorrect1 = jnp.sum((rank < 1).astype(jnp.int32))
    ncorrect5 = jnp.sum((rank < 5).astype(jnp.int32))
    return loss, ncorrect1, ncorrect5


def cross_entropy_grad(logits, labels, dloss=1.0):
    """d(sum_loss)/dlogits — used to check the custom VJP of the kernel."""
    logits32 = logits.astype(jnp.float32)
    p = jnp.exp(logits32 - jnp.max(logits32, axis=-1, keepdims=True))
    p = p / jnp.sum(p, axis=-1, keepdims=True)
    onehot = jnp.zeros_like(logits32).at[jnp.arange(logits.shape[0]), labels].set(1.0)
    return ((p - onehot) * dloss).astype(logits.dtype)


def weight_average(stacked):
    """Reference for kernels.avg.weight_average: mean over leading axis.

    stacked: (W, N) — W worker copies of a flattened tensor. Accumulates in
    f32 (phase 3 of SWAP averages in full precision even if weights are bf16).
    """
    return jnp.mean(stacked.astype(jnp.float32), axis=0).astype(stacked.dtype)
