"""L1: MXU-tiled matmul with fused bias + activation, as a Pallas kernel.

This is the hot-spot of the whole stack: every convolution in the ResNet9s
model (python/compile/model.py) is lowered to im2col + this kernel, and the
classifier head calls it directly — exactly the TPU-idiomatic adaptation of
the paper's cuDNN/V100 convolutions (see DESIGN.md §Hardware-Adaptation).

TPU mapping
-----------
* The (bm, bk) x (bk, bn) tiles are the HBM→VMEM schedule, expressed with
  `BlockSpec` index maps instead of CUDA threadblocks.
* The accumulator lives in a VMEM scratch buffer (`pltpu.VMEM`) and is only
  written back to HBM on the last K-step — one HBM write per output tile.
* `jnp.dot(..., preferred_element_type=f32)` targets the MXU systolic array:
  bf16 or f32 operands, f32 accumulation.
* grid = (M/bm, N/bn, K/bk) with K innermost so the accumulator is reused
  across the contraction (the "revisiting" pattern).

CPU AOT note: the kernel is lowered with `interpret=True` (a Mosaic
custom-call cannot run on the CPU PJRT plugin). Interpret-mode lowering
turns the grid into an XLA loop of dynamic-slices, so for the AOT artifacts
we pick large blocks (often a single K/N block) and let XLA fuse the body;
multi-tile grids are exercised by the pytest/hypothesis suite to validate
the TPU schedule. Block sizes are overridable via SWAP_BM/SWAP_BK/SWAP_BN
for the §Perf experiments.

Differentiation: `matmul_bias_act` carries a custom VJP whose backward pass
reuses this same kernel (dA = dZ @ B^T, dB = A^T @ dZ), so the backward
matmuls also run on the MXU path.
"""

import functools
import os

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _ceil_to(x: int, m: int) -> int:
    return ((x + m - 1) // m) * m


def default_blocks(m: int, k: int, n: int) -> tuple[int, int, int]:
    """Pick (bm, bk, bn) for the given problem.

    On a real TPU we would pick (128, 128, 128)-ish tiles to match the MXU
    and an ~16 MiB VMEM budget; for the CPU-AOT path large blocks minimize
    interpret-mode grid overhead. Env overrides: SWAP_BM / SWAP_BK / SWAP_BN.
    """
    bm = int(os.environ.get("SWAP_BM", 0)) or min(_ceil_to(m, 8), 2048)
    bk = int(os.environ.get("SWAP_BK", 0)) or min(_ceil_to(k, 8), 2048)
    bn = int(os.environ.get("SWAP_BN", 0)) or min(_ceil_to(n, 8), 512)
    return bm, bk, bn


def vmem_bytes(bm: int, bk: int, bn: int, dtype_bytes: int = 4) -> int:
    """VMEM footprint estimate of one program instance (A, B, acc, out).

    Used by DESIGN.md/EXPERIMENTS.md to check the TPU tile choice fits the
    ~16 MiB/core VMEM budget with double-buffering (×2 on the inputs).
    """
    a = bm * bk * dtype_bytes * 2  # double-buffered input tile
    b = bk * bn * dtype_bytes * 2
    acc = bm * bn * 4              # f32 accumulator
    out = bm * bn * dtype_bytes
    return a + b + acc + out


def _matmul_kernel(a_ref, b_ref, o_ref, acc_ref, *, nk: int, activation: str,
                   bias_ref=None):
    """One (i, j, k) grid step: acc += A_tile @ B_tile; epilogue on last k."""
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jnp.dot(a_ref[...], b_ref[...],
                            preferred_element_type=jnp.float32)

    @pl.when(k == nk - 1)
    def _epilogue():
        out = acc_ref[...]
        if bias_ref is not None:
            out = out + bias_ref[...].astype(jnp.float32)
        if activation == "relu":
            out = jnp.maximum(out, 0.0)
        o_ref[...] = out.astype(o_ref.dtype)


def _matmul_raw(a, b, bias, activation, blocks=None):
    """Padded, tiled pallas_call. a: (M, K), b: (K, N), bias: (N,) or None."""
    m, k = a.shape
    k2, n = b.shape
    assert k == k2, (a.shape, b.shape)
    bm, bk, bn = blocks or default_blocks(m, k, n)
    bm, bk, bn = min(bm, _ceil_to(m, 8)), min(bk, _ceil_to(k, 8)), min(bn, _ceil_to(n, 8))
    mp, kp, np_ = _ceil_to(m, bm), _ceil_to(k, bk), _ceil_to(n, bn)
    # Zero padding is exact for matmul + bias; relu(0 + bias_pad=0) = 0.
    if (mp, kp) != (m, k):
        a = jnp.pad(a, ((0, mp - m), (0, kp - k)))
    if (kp, np_) != (k, n):
        b = jnp.pad(b, ((0, kp - k), (0, np_ - n)))
    nk = kp // bk
    grid = (mp // bm, np_ // bn, nk)

    in_specs = [
        pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
        pl.BlockSpec((bk, bn), lambda i, j, kk: (kk, j)),
    ]
    args = [a, b]
    if bias is not None:
        bias2 = bias.reshape(1, -1)
        if np_ != n:
            bias2 = jnp.pad(bias2, ((0, 0), (0, np_ - n)))
        in_specs.append(pl.BlockSpec((1, bn), lambda i, j, kk: (0, j)))
        args.append(bias2)
        kernel = functools.partial(_matmul_kernel_bias, nk=nk,
                                   activation=activation)
    else:
        kernel = functools.partial(_matmul_kernel, nk=nk,
                                   activation=activation)

    out = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=in_specs,
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((mp, np_), a.dtype),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.float32)],
        interpret=True,
    )(*args)
    if (mp, np_) != (m, n):
        out = out[:m, :n]
    return out


def _matmul_kernel_bias(a_ref, b_ref, bias_ref, o_ref, acc_ref, *, nk, activation):
    _matmul_kernel(a_ref, b_ref, o_ref, acc_ref, nk=nk, activation=activation,
                   bias_ref=bias_ref)


@functools.partial(jax.custom_vjp, nondiff_argnums=(3, 4))
def matmul_bias_act_pallas(a, b, bias, activation="none", blocks=None):
    """act(a @ b + bias) with f32 accumulation, as an MXU-tiled Pallas kernel.

    a: (M, K); b: (K, N); bias: (N,) or None; activation in {"none", "relu"}.
    Differentiable (custom VJP, backward reuses the same kernel).
    """
    return _matmul_raw(a, b, bias, activation, blocks)


def matmul_bias_act_xla(a, b, bias, activation="none"):
    """XLA-native twin of the Pallas kernel — identical semantics (f32
    accumulation, fused bias + activation by the XLA fusion pass).

    This is the CPU-backend dispatch target: interpret-mode Pallas lowers
    the tiled grid to an HLO loop of dynamic-slices that XLA-CPU cannot
    fuse (~2x slower, see EXPERIMENTS.md §Perf L1), so the big AOT presets
    emit this path while the `tiny` preset keeps the full Pallas lowering
    exercised end-to-end. On TPU the Pallas path is the performant one.
    """
    out = jnp.dot(a.astype(jnp.float32), b.astype(jnp.float32),
                  preferred_element_type=jnp.float32)
    if bias is not None:
        out = out + bias.astype(jnp.float32)[None, :]
    if activation == "relu":
        out = jnp.maximum(out, 0.0)
    return out.astype(a.dtype)


def matmul_bias_act(a, b, bias, activation="none", blocks=None, backend="pallas"):
    """Backend-dispatched matmul+bias+activation (the model's hot-spot op).

    backend: "pallas" (MXU-tiled kernel; TPU path, default) or "xla"
    (native dot; fast path for CPU-PJRT AOT artifacts). Both share the
    same reference oracle (ref.matmul_bias_act) in the test suite.
    """
    if backend == "xla":
        return matmul_bias_act_xla(a, b, bias, activation)
    return matmul_bias_act_pallas(a, b, bias, activation, blocks)


def _mba_fwd(a, b, bias, activation, blocks):
    out = _matmul_raw(a, b, bias, activation, blocks)
    return out, (a, b, out if activation == "relu" else None,
                 bias is not None)


def _mba_bwd(activation, blocks, res, dz):
    a, b, relu_out, has_bias = res
    if activation == "relu":
        dz = jnp.where(relu_out > 0, dz, jnp.zeros_like(dz))
    # Backward matmuls on the same MXU kernel.
    da = _matmul_raw(dz, b.T, None, "none", blocks)
    db = _matmul_raw(a.T, dz, None, "none", blocks)
    dbias = jnp.sum(dz, axis=0).astype(dz.dtype) if has_bias else None
    return da.astype(a.dtype), db.astype(b.dtype), dbias


matmul_bias_act_pallas.defvjp(_mba_fwd, _mba_bwd)
