"""AOT exporter: lower the L2/L1 graphs once to HLO text + manifest.json.

Usage (from python/):  python -m compile.aot --preset all --out ../artifacts

For every preset this writes

    artifacts/<preset>/grad_b{B}.hlo.txt     phase-1 gradient executable
    artifacts/<preset>/train_b{B}.hlo.txt    phase-2 fused train step
    artifacts/<preset>/eval_b{B}.hlo.txt     evaluation (running BN stats)
    artifacts/<preset>/bnstats_b{B}.hlo.txt  phase-3 BN-moment recompute
    artifacts/<preset>/manifest.json         layout contract for rust

Interchange format is **HLO text**, not a serialized HloModuleProto: jax
>= 0.5 emits protos with 64-bit instruction ids that the xla crate's
xla_extension 0.5.1 rejects; the text parser reassigns ids and round-trips
cleanly (see /opt/xla-example/README.md). Lowered with return_tuple=True;
the rust side unwraps the tuple.

Python runs exactly once, at build time. `make artifacts` skips this when
inputs are unchanged.
"""

import argparse
import dataclasses
import hashlib
import json
import math
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model as M

# ---------------------------------------------------------------------------
# Presets. Scaled-down substitutes for the paper's workloads (DESIGN.md):
# widths/epochs shrink to single-CPU-core scale, topology and training
# procedure stay faithful. `tiny` exists for fast unit/integration tests.
# ---------------------------------------------------------------------------
PRESETS = {
    # tiny keeps the full Pallas matmul path so the rust integration tests
    # and the e2e example exercise Pallas-lowered HLO; the big presets use
    # the XLA-native matmul twin on CPU (see kernels/matmul.py docstring).
    "tiny": dict(width=4, num_classes=10, image_size=16, batches=(8,),
                 matmul_backend="pallas"),
    "cifar10sim": dict(width=8, num_classes=10, image_size=32, batches=(64,),
                       matmul_backend="xla"),
    "cifar100sim": dict(width=8, num_classes=100, image_size=32, batches=(64,),
                        matmul_backend="xla"),
    "imagenetsim": dict(width=12, num_classes=64, image_size=32, batches=(64,),
                        matmul_backend="xla"),
}


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (the 0.5.1-safe interchange)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True)
    return comp.as_hlo_text()


def conv_flops_per_example(cfg: M.ModelConfig) -> int:
    """Forward multiply-add FLOPs of all convs + head for one example."""
    hw = cfg.image_size * cfg.image_size
    sizes = {  # spatial size at each conv (after the preceding pools)
        "prep": hw, "layer1": hw, "res1a": hw // 4, "res1b": hw // 4,
        "layer2": hw // 4, "layer3": hw // 16, "res3a": hw // 64,
        "res3b": hw // 64,
    }
    total = 0
    for name, cin, cout in M._conv_layers(cfg):
        total += 2 * sizes[name] * (9 * cin) * cout
    total += 2 * cfg.channels["res3"] * cfg.num_classes
    return total


def export_preset(name: str, out_root: str, batches=None) -> dict:
    spec = PRESETS[name]
    cfg = M.ModelConfig(width=spec["width"], num_classes=spec["num_classes"],
                        image_size=spec["image_size"],
                        matmul_backend=os.environ.get("SWAP_MATMUL_BACKEND",
                                                      spec["matmul_backend"]))
    batches = tuple(batches or spec["batches"])
    out_dir = os.path.join(out_root, name)
    os.makedirs(out_dir, exist_ok=True)

    pspecs = M.param_specs(cfg)
    bspecs = M.bn_specs(cfg)
    f32 = jnp.float32
    p_avals = [jax.ShapeDtypeStruct(s, f32) for _, s in pspecs]
    bn_avals = [jax.ShapeDtypeStruct(s, f32) for _, s in bspecs]
    img = cfg.image_size

    executables = {}

    def emit(fname, fn, *avals):
        # keep_unused: the rust side always feeds the FULL param list; jit
        # must not prune inputs that a particular entry point ignores
        # (e.g. bnstats does not read the head weights).
        lowered = jax.jit(fn, keep_unused=True).lower(*avals)
        text = to_hlo_text(lowered)
        path = os.path.join(out_dir, fname)
        with open(path, "w") as f:
            f.write(text)
        executables[fname.replace(".hlo.txt", "")] = fname
        print(f"  {name}/{fname}: {len(text)} chars")

    for b in batches:
        im = jax.ShapeDtypeStruct((b, img, img, 3), f32)
        lb = jax.ShapeDtypeStruct((b,), jnp.int32)
        lr = jax.ShapeDtypeStruct((1,), f32)

        emit(f"grad_b{b}.hlo.txt",
             lambda *a, b=b: M.grad_step(cfg, list(a[:len(p_avals)]), a[-2], a[-1]),
             *p_avals, im, lb)
        emit(f"train_b{b}.hlo.txt",
             lambda *a, b=b: M.train_step(
                 cfg, list(a[:len(p_avals)]),
                 list(a[len(p_avals):2 * len(p_avals)]), a[-3], a[-2], a[-1]),
             *p_avals, *p_avals, im, lb, lr)
        emit(f"eval_b{b}.hlo.txt",
             lambda *a, b=b: M.eval_step(
                 cfg, list(a[:len(p_avals)]),
                 list(a[len(p_avals):len(p_avals) + len(bn_avals)]), a[-2], a[-1]),
             *p_avals, *bn_avals, im, lb)
        emit(f"bnstats_b{b}.hlo.txt",
             lambda *a, b=b: M.bnstats_step(cfg, list(a[:len(p_avals)]), a[-1]),
             *p_avals, im)

    manifest = {
        "preset": name,
        "model": {
            "arch": "resnet9s",
            "width": cfg.width,
            "num_classes": cfg.num_classes,
            "image_size": cfg.image_size,
            "momentum": cfg.momentum,
            "weight_decay": cfg.weight_decay,
            "head_scale": M.HEAD_SCALE,
            "bn_eps": M.BN_EPS,
            "matmul_backend": cfg.matmul_backend,
        },
        "params": [{"name": n, "shape": list(s)} for n, s in pspecs],
        "bn_stats": [{"name": n, "shape": list(s)} for n, s in bspecs],
        "num_params": M.num_params(cfg),
        "batches": list(batches),
        "executables": executables,
        "flops_fwd_per_example": conv_flops_per_example(cfg),
        # Interface contract (also documented in rust/src/runtime/manifest.rs):
        "interface": {
            "grad": "in: params..., images(B,H,W,3)f32, labels(B,)i32 | out: grads..., sum_loss f32, ncorrect1 i32, ncorrect5 i32",
            "train": "in: params..., momentum..., images, labels, lr(1,)f32 | out: params'..., momentum'..., sum_loss, ncorrect1, ncorrect5",
            "eval": "in: params..., bn_stats..., images, labels | out: sum_loss, ncorrect1, ncorrect5",
            "bnstats": "in: params..., images | out: bn_moments... (bn_stats order)",
        },
    }
    with open(os.path.join(out_dir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=1)
    return manifest


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--preset", default="all",
                    help="preset name or 'all' (%s)" % ",".join(PRESETS))
    ap.add_argument("--out", default="../artifacts")
    ap.add_argument("--batches", default=None,
                    help="comma-separated batch-size override")
    args = ap.parse_args()
    batches = [int(x) for x in args.batches.split(",")] if args.batches else None
    names = list(PRESETS) if args.preset == "all" else [args.preset]
    for n in names:
        print(f"exporting preset {n} ...")
        m = export_preset(n, args.out, batches)
        print(f"  num_params={m['num_params']} "
              f"fwd_flops/example={m['flops_fwd_per_example']}")
    # Stamp so `make artifacts` can skip re-runs when inputs are unchanged.
    with open(os.path.join(args.out, ".stamp"), "w") as f:
        f.write("ok\n")


if __name__ == "__main__":
    main()
