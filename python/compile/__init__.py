"""swap-train build-time package: L2 model + L1 kernels + AOT exporter.

Nothing in this package runs at serving/training time — `make artifacts`
lowers everything to HLO text once, and the rust coordinator executes the
artifacts through PJRT (see DESIGN.md).
"""
