"""L2: ResNet9s — the paper's CIFAR network, functional JAX, Pallas-backed.

The paper trains the "fast-to-train custom ResNet 9" from davidcpage's
DAWNBench submission (§5.1). This module reproduces that topology:

    prep  : conv3x3( 3 ->  c) + BN + ReLU
    layer1: conv3x3( c -> 2c) + BN + ReLU + maxpool2
    res1  : 2 x [conv3x3(2c -> 2c) + BN + ReLU]   (residual)
    layer2: conv3x3(2c -> 4c) + BN + ReLU + maxpool2
    layer3: conv3x3(4c -> 8c) + BN + ReLU + maxpool2
    res3  : 2 x [conv3x3(8c -> 8c) + BN + ReLU]   (residual)
    head  : global maxpool + linear(8c -> classes) * 0.125

Every convolution is lowered to **im2col + the L1 Pallas MXU matmul**
(kernels.matmul) — the TPU-idiomatic replacement for the paper's cuDNN
convs; see DESIGN.md §Hardware-Adaptation. BatchNorm uses batch statistics
in training mode; evaluation takes externally supplied running statistics
(the rust coordinator recomputes them in SWAP phase 3 via the `bnstats_b*`
executable, exactly as Algorithm 1 line 28 prescribes).

Parameters travel across the rust<->HLO boundary as a *flat ordered list*
of tensors; `param_specs()` / `bn_specs()` define the order and are written
into artifacts/<preset>/manifest.json by aot.py.

All functions here are pure; nothing is jitted at import time.
"""

import dataclasses
import math

import jax
import jax.numpy as jnp

from .kernels import cross_entropy, matmul_bias_act, sgd_nesterov

BN_EPS = 1e-5
HEAD_SCALE = 0.125  # davidcpage head scaling


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    """Static architecture configuration (baked into the AOT artifacts)."""

    width: int = 8          # base channel count c
    num_classes: int = 10
    image_size: int = 32    # square images, NHWC
    momentum: float = 0.9   # Nesterov momentum (paper §5.1)
    weight_decay: float = 5e-4
    # matmul kernel backend: "pallas" (TPU/MXU path; tiny preset keeps it
    # on CPU so the full Pallas lowering is exercised end-to-end) or "xla"
    # (CPU fast path; see kernels/matmul.py + EXPERIMENTS.md §Perf L1)
    matmul_backend: str = "pallas"

    @property
    def channels(self):
        c = self.width
        return dict(prep=c, layer1=2 * c, res1=2 * c, layer2=4 * c,
                    layer3=8 * c, res3=8 * c)


# Conv layers in forward order: (name, cin_key or None for input, cout_key,
# has two convs if residual). Flattened to per-conv entries below.
def _conv_layers(cfg: ModelConfig):
    ch = cfg.channels
    return [
        ("prep", 3, ch["prep"]),
        ("layer1", ch["prep"], ch["layer1"]),
        ("res1a", ch["layer1"], ch["res1"]),
        ("res1b", ch["res1"], ch["res1"]),
        ("layer2", ch["layer1"], ch["layer2"]),
        ("layer3", ch["layer2"], ch["layer3"]),
        ("res3a", ch["layer3"], ch["res3"]),
        ("res3b", ch["res3"], ch["res3"]),
    ]


def param_specs(cfg: ModelConfig):
    """Ordered (name, shape) list — the manifest/rust param layout."""
    specs = []
    for name, cin, cout in _conv_layers(cfg):
        specs.append((f"{name}.w", (cin * 9, cout)))
        specs.append((f"{name}.gamma", (cout,)))
        specs.append((f"{name}.beta", (cout,)))
    c8 = cfg.channels["res3"]
    specs.append(("head.w", (c8, cfg.num_classes)))
    specs.append(("head.b", (cfg.num_classes,)))
    return specs


def bn_specs(cfg: ModelConfig):
    """Ordered (name, shape) list of batch-norm running statistics.

    For each conv layer there is a mean and a var vector over channels; the
    order matches the order bn moments are emitted by `forward(train=True)`.
    """
    specs = []
    for name, _cin, cout in _conv_layers(cfg):
        specs.append((f"{name}.mean", (cout,)))
        specs.append((f"{name}.var", (cout,)))
    return specs


def num_params(cfg: ModelConfig) -> int:
    return sum(math.prod(s) for _, s in param_specs(cfg))


def init_params(cfg: ModelConfig, seed: int = 0):
    """He-normal conv init, BN gamma=1/beta=0, zero head bias.

    Returns the flat ordered list matching `param_specs`.
    """
    key = jax.random.PRNGKey(seed)
    params = []
    for name, shape in param_specs(cfg):
        key, sub = jax.random.split(key)
        if name.endswith(".w"):
            fan_in = shape[0]
            params.append(jax.random.normal(sub, shape, jnp.float32)
                          * jnp.sqrt(2.0 / fan_in))
        elif name.endswith(".gamma"):
            params.append(jnp.ones(shape, jnp.float32))
        else:  # beta, head.b
            params.append(jnp.zeros(shape, jnp.float32))
    return params


def init_bn_stats(cfg: ModelConfig):
    stats = []
    for name, shape in bn_specs(cfg):
        stats.append(jnp.zeros(shape, jnp.float32) if name.endswith(".mean")
                     else jnp.ones(shape, jnp.float32))
    return stats


def im2col(x):
    """(B, H, W, C) -> (B*H*W, 9*C) patches for a 3x3 SAME convolution.

    Patch channel order is (dy, dx, c) row-major — conv weights are stored
    in exactly this (9*Cin, Cout) layout. Explicit shifted-slice
    construction (no gather) so XLA lowers it to pad+slice+concat, which
    fuses with the downstream matmul's HBM reads.
    """
    b, h, w, c = x.shape
    xp = jnp.pad(x, ((0, 0), (1, 1), (1, 1), (0, 0)))
    rows = []
    for dy in range(3):
        for dx in range(3):
            rows.append(xp[:, dy:dy + h, dx:dx + w, :])
    patches = jnp.concatenate(rows, axis=-1)  # (B, H, W, 9*C)
    return patches.reshape(b * h * w, 9 * c)


def conv3x3(x, w, backend="pallas"):
    """3x3 SAME conv via im2col + the MXU matmul kernel (backend-dispatched).
    x: (B,H,W,C)->(B,H,W,Cout)."""
    b, h, wd, _c = x.shape
    cout = w.shape[1]
    out = matmul_bias_act(im2col(x), w, None, "none", backend=backend)
    return out.reshape(b, h, wd, cout)


def batchnorm_train(x, gamma, beta):
    """BN with batch statistics. Returns (y, (mean, var)) — biased var,
    matching what the bnstats executable accumulates for evaluation."""
    mean = jnp.mean(x, axis=(0, 1, 2))
    var = jnp.var(x, axis=(0, 1, 2))
    y = (x - mean) * jax.lax.rsqrt(var + BN_EPS) * gamma + beta
    return y, (mean, var)


def batchnorm_eval(x, gamma, beta, mean, var):
    return (x - mean) * jax.lax.rsqrt(var + BN_EPS) * gamma + beta


def maxpool2(x):
    return jax.lax.reduce_window(x, -jnp.inf, jax.lax.max,
                                 (1, 2, 2, 1), (1, 2, 2, 1), "VALID")


def global_maxpool(x):
    return jnp.max(x, axis=(1, 2))


def forward(cfg: ModelConfig, params, images, train: bool, bn_stats=None):
    """ResNet9s forward pass.

    images: (B, H, W, 3) f32 in [-1, 1]-ish (normalization happens in the
    rust data pipeline). Returns (logits, bn_moments) where bn_moments is a
    flat [mean0, var0, mean1, var1, ...] list in `bn_specs` order when
    train=True, else [].
    """
    p = {name: t for (name, _), t in zip(param_specs(cfg), params)}
    if not train:
        s = {name: t for (name, _), t in zip(bn_specs(cfg), bn_stats)}
    moments = []

    def block(x, name):
        x = conv3x3(x, p[f"{name}.w"], backend=cfg.matmul_backend)
        if train:
            x, (mean, var) = batchnorm_train(x, p[f"{name}.gamma"], p[f"{name}.beta"])
            moments.extend([mean, var])
        else:
            x = batchnorm_eval(x, p[f"{name}.gamma"], p[f"{name}.beta"],
                               s[f"{name}.mean"], s[f"{name}.var"])
        return jnp.maximum(x, 0.0)

    x = block(images, "prep")
    x = maxpool2(block(x, "layer1"))
    x = x + block(block(x, "res1a"), "res1b")
    x = maxpool2(block(x, "layer2"))
    x = maxpool2(block(x, "layer3"))
    x = x + block(block(x, "res3a"), "res3b")
    x = global_maxpool(x)
    logits = matmul_bias_act(x, p["head.w"], p["head.b"], "none",
                             backend=cfg.matmul_backend) * HEAD_SCALE
    return logits, moments


def loss_fn(cfg: ModelConfig, params, images, labels):
    """Training loss: mean cross-entropy. Returns (mean_loss,
    (sum_loss, ncorrect1, ncorrect5)) so grad flows through the mean."""
    logits, _ = forward(cfg, params, images, train=True)
    sum_loss, c1, c5 = cross_entropy(logits, labels)
    batch = images.shape[0]
    return sum_loss / batch, (sum_loss, c1, c5)


# --------------------------------------------------------------------------
# The four exported entry points (lowered per preset x batch size by aot.py)
# --------------------------------------------------------------------------

def grad_step(cfg: ModelConfig, params, images, labels):
    """Phase-1 executable: gradients only (the all-reduce + optimizer update
    happen in rust between executions). Outputs grads in param order, then
    (sum_loss, ncorrect1, ncorrect5)."""
    (_, aux), grads = jax.value_and_grad(
        lambda ps: loss_fn(cfg, ps, images, labels), has_aux=True)(params)
    sum_loss, c1, c5 = aux
    return (*grads, sum_loss, c1, c5)


def train_step(cfg: ModelConfig, params, momentum, images, labels, lr):
    """Phase-2 executable: fused grad + Nesterov-SGD update on device, using
    the L1 sgd kernel. Outputs (params'..., momentum'..., sum_loss, c1, c5)."""
    (_, aux), grads = jax.value_and_grad(
        lambda ps: loss_fn(cfg, ps, images, labels), has_aux=True)(params)
    sum_loss, c1, c5 = aux
    new_p, new_m = [], []
    for pt, mt, gt in zip(params, momentum, grads):
        p2, m2 = sgd_nesterov(pt, mt, gt, lr, mu=cfg.momentum,
                              wd=cfg.weight_decay)
        new_p.append(p2)
        new_m.append(m2)
    return (*new_p, *new_m, sum_loss, c1, c5)


def eval_step(cfg: ModelConfig, params, bn_stats, images, labels):
    """Evaluation executable: forward with running BN statistics.
    Outputs (sum_loss, ncorrect1, ncorrect5)."""
    logits, _ = forward(cfg, params, images, train=False, bn_stats=bn_stats)
    return cross_entropy(logits, labels)


def bnstats_step(cfg: ModelConfig, params, images):
    """Phase-3 executable: batch-norm moments of one batch (Algorithm 1,
    line 28). The rust coordinator averages moments over several batches to
    build the running statistics used by `eval_step`."""
    _, moments = forward(cfg, params, images, train=True)
    return tuple(moments)
