"""L1 correctness: every Pallas kernel vs its pure-jnp oracle.

Hypothesis sweeps shapes and dtypes (the f32/bf16 MXU pair), including
shapes that do NOT divide the block sizes (exercising the padding path)
and multi-tile grids (exercising the accumulator revisiting pattern that
the TPU schedule relies on).
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import (cross_entropy, matmul_bias_act, sgd_nesterov,
                             weight_average)
from compile.kernels import ref
from compile.kernels.matmul import default_blocks, vmem_bytes

jax.config.update("jax_platform_name", "cpu")

DTYPES = [jnp.float32, jnp.bfloat16]


def tol(dtype):
    return dict(atol=2e-2, rtol=2e-2) if dtype == jnp.bfloat16 else \
        dict(atol=2e-5, rtol=2e-5)


def rand(rng, shape, dtype):
    return jnp.asarray(rng.standard_normal(shape), dtype)


# ---------------------------------------------------------------------------
# matmul_bias_act
# ---------------------------------------------------------------------------

@settings(max_examples=25, deadline=None)
@given(
    m=st.integers(1, 70), k=st.integers(1, 70), n=st.integers(1, 70),
    dt=st.sampled_from(DTYPES),
    bias=st.booleans(), act=st.sampled_from(["none", "relu"]),
)
def test_matmul_matches_ref(m, k, n, dt, bias, act):
    rng = np.random.default_rng(m * 10007 + k * 101 + n)
    a, b = rand(rng, (m, k), dt), rand(rng, (k, n), dt)
    bv = rand(rng, (n,), dt) if bias else None
    out = matmul_bias_act(a, b, bv, act)
    expect = ref.matmul_bias_act(a, b, bv, act)
    assert out.dtype == dt and out.shape == (m, n)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(expect, np.float32), **tol(dt))


@settings(max_examples=10, deadline=None)
@given(
    bm=st.sampled_from([8, 16, 32]), bk=st.sampled_from([8, 16, 32]),
    bn=st.sampled_from([8, 16, 32]),
)
def test_matmul_multitile_grid(bm, bk, bn):
    """Multi-tile grids (the real TPU schedule) must agree with ref."""
    rng = np.random.default_rng(bm * 100 + bk * 10 + bn)
    m, k, n = 3 * bm + 5, 2 * bk + 3, 2 * bn + 1  # force padding + revisits
    a, b = rand(rng, (m, k), jnp.float32), rand(rng, (k, n), jnp.float32)
    out = matmul_bias_act(a, b, None, "none", (bm, bk, bn))
    np.testing.assert_allclose(np.asarray(out),
                               np.asarray(ref.matmul_bias_act(a, b)),
                               atol=2e-5, rtol=2e-5)


def test_matmul_grad_matches_ref_grad():
    rng = np.random.default_rng(7)
    a = rand(rng, (33, 21), jnp.float32)
    b = rand(rng, (21, 17), jnp.float32)
    bias = rand(rng, (17,), jnp.float32)
    co = rand(rng, (33, 17), jnp.float32)

    f = lambda a, b, bias: jnp.sum(matmul_bias_act(a, b, bias, "relu") * co)
    fr = lambda a, b, bias: jnp.sum(ref.matmul_bias_act(a, b, bias, "relu") * co)
    g = jax.grad(f, argnums=(0, 1, 2))(a, b, bias)
    gr = jax.grad(fr, argnums=(0, 1, 2))(a, b, bias)
    for x, y in zip(g, gr):
        np.testing.assert_allclose(np.asarray(x), np.asarray(y),
                                   atol=1e-4, rtol=1e-4)


def test_matmul_relu_masks_negative():
    a = jnp.array([[1.0, -1.0]], jnp.float32)
    b = jnp.array([[1.0], [2.0]], jnp.float32)
    out = matmul_bias_act(a, b, None, "relu")  # 1 - 2 = -1 -> 0
    assert float(out[0, 0]) == 0.0


def test_default_blocks_and_vmem_budget():
    bm, bk, bn = default_blocks(4096, 1152, 128)
    assert bm % 8 == 0 and bk % 8 == 0 and bn % 8 == 0
    # The documented TPU tile must fit a 16 MiB VMEM with double buffering.
    assert vmem_bytes(128, 128, 128, 2) < 16 * 1024 * 1024


# ---------------------------------------------------------------------------
# sgd_nesterov
# ---------------------------------------------------------------------------

@settings(max_examples=25, deadline=None)
@given(
    n=st.integers(1, 5000), dt=st.sampled_from(DTYPES),
    lr=st.floats(1e-4, 1.0), mu=st.sampled_from([0.0, 0.9, 0.99]),
    wd=st.sampled_from([0.0, 5e-4, 1e-2]),
    block=st.sampled_from([64, 1024, 1 << 16]),
)
def test_sgd_matches_ref(n, dt, lr, mu, wd, block):
    rng = np.random.default_rng(n)
    p, m, g = (rand(rng, (n,), dt) for _ in range(3))
    p2, m2 = sgd_nesterov(p, m, g, lr, mu=mu, wd=wd, block=block)
    p2r, m2r = ref.sgd_nesterov(p, m, g, lr, mu=mu, wd=wd)
    np.testing.assert_allclose(np.asarray(p2, np.float32),
                               np.asarray(p2r, np.float32), **tol(dt))
    np.testing.assert_allclose(np.asarray(m2, np.float32),
                               np.asarray(m2r, np.float32), **tol(dt))


def test_sgd_multidim_shape_preserved():
    rng = np.random.default_rng(0)
    p = rand(rng, (9, 7, 5), jnp.float32)
    m, g = jnp.zeros_like(p), rand(rng, (9, 7, 5), jnp.float32)
    p2, m2 = sgd_nesterov(p, m, g, 0.1, mu=0.9, wd=0.0)
    assert p2.shape == p.shape and m2.shape == p.shape
    # mu with zero momentum buffer: p2 = p - lr*(1+mu)*g
    np.testing.assert_allclose(np.asarray(p2), np.asarray(p - 0.1 * 1.9 * g),
                               atol=1e-5, rtol=1e-5)


def test_sgd_zero_lr_is_identity_on_params():
    rng = np.random.default_rng(1)
    p = rand(rng, (100,), jnp.float32)
    m = rand(rng, (100,), jnp.float32)
    g = rand(rng, (100,), jnp.float32)
    p2, m2 = sgd_nesterov(p, m, g, 0.0, mu=0.9, wd=5e-4)
    np.testing.assert_allclose(np.asarray(p2), np.asarray(p), atol=0, rtol=0)


# ---------------------------------------------------------------------------
# cross_entropy
# ---------------------------------------------------------------------------

@settings(max_examples=25, deadline=None)
@given(b=st.integers(1, 130), c=st.integers(2, 150), seed=st.integers(0, 99))
def test_xent_matches_ref(b, c, seed):
    rng = np.random.default_rng(seed)
    logits = jnp.asarray(rng.standard_normal((b, c)) * 3, jnp.float32)
    labels = jnp.asarray(rng.integers(0, c, b), jnp.int32)
    loss, c1, c5 = cross_entropy(logits, labels)
    lr_, c1r, c5r = ref.cross_entropy(logits, labels)
    np.testing.assert_allclose(float(loss), float(lr_), atol=1e-3, rtol=1e-5)
    assert int(c1) == int(c1r) and int(c5) == int(c5r)
    assert 0 <= int(c1) <= int(c5) <= b


def test_xent_grad_matches_softmax_minus_onehot():
    rng = np.random.default_rng(3)
    logits = jnp.asarray(rng.standard_normal((17, 11)), jnp.float32)
    labels = jnp.asarray(rng.integers(0, 11, 17), jnp.int32)
    d = jax.grad(lambda lg: cross_entropy(lg, labels)[0])(logits)
    np.testing.assert_allclose(np.asarray(d),
                               np.asarray(ref.cross_entropy_grad(logits, labels)),
                               atol=1e-5, rtol=1e-5)


def test_xent_perfect_prediction():
    logits = jnp.asarray([[10.0, -10.0], [-10.0, 10.0]], jnp.float32)
    labels = jnp.asarray([0, 1], jnp.int32)
    loss, c1, c5 = cross_entropy(logits, labels)
    assert float(loss) < 1e-3 and int(c1) == 2 and int(c5) == 2


def test_xent_top5_vs_top1():
    # true class ranked 2nd -> top1 wrong, top5 right (C >= 6).
    logits = jnp.asarray([[5.0, 4.0, 0.0, 0.0, 0.0, 0.0]], jnp.float32)
    labels = jnp.asarray([1], jnp.int32)
    _, c1, c5 = cross_entropy(logits, labels)
    assert int(c1) == 0 and int(c5) == 1


def test_xent_large_logits_stable():
    logits = jnp.asarray([[1000.0, 999.0]], jnp.float32)
    labels = jnp.asarray([0], jnp.int32)
    loss, _, _ = cross_entropy(logits, labels)
    assert np.isfinite(float(loss))


# ---------------------------------------------------------------------------
# weight_average
# ---------------------------------------------------------------------------

@settings(max_examples=25, deadline=None)
@given(w=st.integers(1, 16), n=st.integers(1, 3000),
       block=st.sampled_from([32, 512, 1 << 16]), dt=st.sampled_from(DTYPES))
def test_avg_matches_ref(w, n, block, dt):
    rng = np.random.default_rng(w * 1000 + n)
    s = rand(rng, (w, n), dt)
    out = weight_average(s, block=block)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref.weight_average(s), np.float32),
                               **tol(dt))


def test_avg_of_identical_models_is_identity():
    rng = np.random.default_rng(5)
    x = jnp.asarray(rng.standard_normal(257), jnp.float32)
    s = jnp.stack([x] * 8)
    # f32 accumulate-then-divide leaves ~1ulp of noise
    np.testing.assert_allclose(np.asarray(weight_average(s)), np.asarray(x),
                               atol=1e-6, rtol=0)


def test_avg_is_convex_combination():
    """mean must lie inside [min, max] elementwise — phase-3 geometry."""
    rng = np.random.default_rng(6)
    s = jnp.asarray(rng.standard_normal((5, 100)), jnp.float32)
    out = np.asarray(weight_average(s))
    assert (out <= np.asarray(s).max(0) + 1e-6).all()
    assert (out >= np.asarray(s).min(0) - 1e-6).all()


# ---------------------------------------------------------------------------
# backend dispatch (CPU fast path vs Pallas path)
# ---------------------------------------------------------------------------

@settings(max_examples=15, deadline=None)
@given(m=st.integers(1, 50), k=st.integers(1, 50), n=st.integers(1, 50),
       bias=st.booleans(), act=st.sampled_from(["none", "relu"]))
def test_matmul_backends_agree(m, k, n, bias, act):
    """The XLA-native twin must match the Pallas kernel exactly (same f32
    accumulation) — the AOT presets dispatch between them."""
    rng = np.random.default_rng(m * 31 + k * 7 + n)
    a = jnp.asarray(rng.standard_normal((m, k)), jnp.float32)
    b = jnp.asarray(rng.standard_normal((k, n)), jnp.float32)
    bv = jnp.asarray(rng.standard_normal(n), jnp.float32) if bias else None
    pal = matmul_bias_act(a, b, bv, act, backend="pallas")
    xla = matmul_bias_act(a, b, bv, act, backend="xla")
    np.testing.assert_allclose(np.asarray(pal), np.asarray(xla),
                               atol=1e-5, rtol=1e-5)


def test_matmul_backend_grads_agree():
    rng = np.random.default_rng(0)
    a = jnp.asarray(rng.standard_normal((20, 12)), jnp.float32)
    b = jnp.asarray(rng.standard_normal((12, 8)), jnp.float32)
    co = jnp.asarray(rng.standard_normal((20, 8)), jnp.float32)
    g_pal = jax.grad(lambda a, b: jnp.sum(
        matmul_bias_act(a, b, None, "relu", backend="pallas") * co),
        argnums=(0, 1))(a, b)
    g_xla = jax.grad(lambda a, b: jnp.sum(
        matmul_bias_act(a, b, None, "relu", backend="xla") * co),
        argnums=(0, 1))(a, b)
    for p, x in zip(g_pal, g_xla):
        np.testing.assert_allclose(np.asarray(p), np.asarray(x),
                                   atol=1e-5, rtol=1e-5)
