"""AOT interchange correctness: the HLO text artifacts parse and expose the
exact interface (parameter count/order/shapes, tuple outputs) that the rust
runtime (rust/src/runtime/manifest.rs) relies on.

Numerics of the compiled artifacts are validated end-to-end on the rust
side (rust/tests/integration_runtime.rs executes the same artifacts through
PjRtClient::cpu and checks them against values recorded here via the
deterministic model); this file pins the *contract*.
"""

import json
import os
import re

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax._src.lib import xla_client as xc

from compile import aot
from compile import model as M

jax.config.update("jax_platform_name", "cpu")

CFG = M.ModelConfig(width=4, num_classes=10, image_size=16)
ART = os.path.join(os.path.dirname(__file__), "..", "..", "artifacts", "tiny")


@pytest.fixture(scope="module")
def tiny_manifest():
    if not os.path.exists(os.path.join(ART, "manifest.json")):
        pytest.skip("artifacts not built (run `make artifacts`)")
    with open(os.path.join(ART, "manifest.json")) as f:
        return json.load(f)


_SHAPE_RE = re.compile(r"(?:f32|s32|pred)\[[\d,]*\](?:\{[\d,]*\})?")


def _entry_layout(path):
    """Parse `entry_computation_layout={(...)->...}` from HLO text."""
    text = open(path).read()
    m = re.search(r"entry_computation_layout=\{\((.*?)\)->(.*?)\}\n", text, re.S)
    assert m, "no entry layout in " + path
    parts = _SHAPE_RE.findall(m.group(1))  # robust to /*index=N*/ comments
    return parts, m.group(2), text


def _shape_of(part):
    m = re.match(r"(f32|s32|pred)\[([\d,]*)\]", part)
    assert m, part
    dims = tuple(int(d) for d in m.group(2).split(",") if d) if m.group(2) else ()
    return m.group(1), dims


def test_manifest_matches_model(tiny_manifest):
    man = tiny_manifest
    assert man["model"]["arch"] == "resnet9s"
    specs = M.param_specs(CFG)
    assert [p["name"] for p in man["params"]] == [n for n, _ in specs]
    assert [tuple(p["shape"]) for p in man["params"]] == [s for _, s in specs]
    assert [tuple(b["shape"]) for b in man["bn_stats"]] == \
        [s for _, s in M.bn_specs(CFG)]
    assert man["num_params"] == M.num_params(CFG)
    for fname in man["executables"].values():
        assert os.path.exists(os.path.join(ART, fname)), fname


def test_hlo_artifacts_parse_back(tiny_manifest):
    """The exact text the rust loader reads must re-parse as an HloModule
    (this is the 64-bit-id-safe interchange from the AOT recipe)."""
    for fname in tiny_manifest["executables"].values():
        text = open(os.path.join(ART, fname)).read()
        mod = xc._xla.hlo_module_from_text(text)
        assert mod.name, fname


def test_grad_interface_arity(tiny_manifest):
    man = tiny_manifest
    b = man["batches"][0]
    npar = len(man["params"])
    ins, out, _ = _entry_layout(os.path.join(ART, f"grad_b{b}.hlo.txt"))
    assert len(ins) == npar + 2  # params..., images, labels
    for spec, part in zip(man["params"], ins):
        assert _shape_of(part) == ("f32", tuple(spec["shape"])), spec["name"]
    assert _shape_of(ins[npar]) == ("f32", (b, 16, 16, 3))
    assert _shape_of(ins[npar + 1]) == ("s32", (b,))
    # tuple out: grads... + (loss, c1, c5)
    assert out.count("f32") >= npar + 1 and out.count("s32[]") == 2


def test_train_interface_arity(tiny_manifest):
    man = tiny_manifest
    b = man["batches"][0]
    npar = len(man["params"])
    ins, out, _ = _entry_layout(os.path.join(ART, f"train_b{b}.hlo.txt"))
    assert len(ins) == 2 * npar + 3  # params, momentum, images, labels, lr
    assert _shape_of(ins[-1]) == ("f32", (1,))
    assert _shape_of(ins[-2]) == ("s32", (b,))
    assert _shape_of(ins[-3]) == ("f32", (b, 16, 16, 3))


def test_eval_interface_arity(tiny_manifest):
    man = tiny_manifest
    b = man["batches"][0]
    npar, nbn = len(man["params"]), len(man["bn_stats"])
    ins, out, _ = _entry_layout(os.path.join(ART, f"eval_b{b}.hlo.txt"))
    assert len(ins) == npar + nbn + 2
    for spec, part in zip(man["bn_stats"], ins[npar:npar + nbn]):
        assert _shape_of(part) == ("f32", tuple(spec["shape"])), spec["name"]


def test_bnstats_interface_arity(tiny_manifest):
    man = tiny_manifest
    b = man["batches"][0]
    npar = len(man["params"])
    ins, out, _ = _entry_layout(os.path.join(ART, f"bnstats_b{b}.hlo.txt"))
    assert len(ins) == npar + 1
    assert _shape_of(ins[-1]) == ("f32", (b, 16, 16, 3))
    # 16 bn tensors of width 4..32 channels in the tuple
    assert out.count("f32") == len(man["bn_stats"])


def test_flops_estimate_positive_and_monotone_in_width():
    small = aot.conv_flops_per_example(M.ModelConfig(width=4))
    big = aot.conv_flops_per_example(M.ModelConfig(width=8))
    assert 0 < small < big


def test_presets_well_formed():
    for name, spec in aot.PRESETS.items():
        assert spec["num_classes"] >= 6, name  # top-5 must be meaningful
        assert spec["image_size"] % 8 == 0, name  # three maxpool2 stages
        assert all(b % 8 == 0 for b in spec["batches"]), name


def test_manifest_deterministic(tmp_path):
    """Re-exporting tiny produces an identical manifest (stable contract)."""
    m1 = aot.export_preset("tiny", str(tmp_path))
    with open(os.path.join(ART, "manifest.json")) as f:
        m2 = json.load(f)
    assert m1 == m2
