"""L2 correctness: ResNet9s shapes, conv-vs-lax oracle, BN, grads, update.

The key oracle here: `conv3x3` (im2col + Pallas matmul) must equal
`jax.lax.conv_general_dilated` — i.e. our TPU-adapted convolution is the
same operator the paper's cuDNN path computes.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile import model as M
from compile.kernels import ref

jax.config.update("jax_platform_name", "cpu")

CFG = M.ModelConfig(width=4, num_classes=10, image_size=16)


def lax_conv3x3(x, w):
    """Oracle conv: NHWC x (9*Cin, Cout) weights -> lax.conv."""
    cin = x.shape[-1]
    cout = w.shape[1]
    # our weight layout is (dy, dx, cin) row-major flattened
    wk = w.reshape(3, 3, cin, cout)
    return jax.lax.conv_general_dilated(
        x, wk, (1, 1), "SAME", dimension_numbers=("NHWC", "HWIO", "NHWC"))


@settings(max_examples=10, deadline=None)
@given(b=st.integers(1, 4), h=st.sampled_from([4, 8]), cin=st.sampled_from([3, 8]),
       cout=st.sampled_from([4, 16]), seed=st.integers(0, 50))
def test_conv3x3_matches_lax_conv(b, h, cin, cout, seed):
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.standard_normal((b, h, h, cin)), jnp.float32)
    w = jnp.asarray(rng.standard_normal((9 * cin, cout)) * 0.1, jnp.float32)
    np.testing.assert_allclose(np.asarray(M.conv3x3(x, w)),
                               np.asarray(lax_conv3x3(x, w)),
                               atol=1e-4, rtol=1e-4)


def test_param_specs_order_and_count():
    specs = M.param_specs(CFG)
    names = [n for n, _ in specs]
    assert names[0] == "prep.w" and names[-1] == "head.b"
    assert len(names) == 8 * 3 + 2  # 8 convs x (w, gamma, beta) + head w/b
    assert len(set(names)) == len(names)
    assert M.num_params(CFG) == sum(int(np.prod(s)) for _, s in specs)


def test_bn_specs_pair_mean_var():
    specs = M.bn_specs(CFG)
    assert len(specs) == 16
    for i in range(0, 16, 2):
        assert specs[i][0].endswith(".mean") and specs[i + 1][0].endswith(".var")
        assert specs[i][1] == specs[i + 1][1]


def test_init_params_match_specs():
    params = M.init_params(CFG, seed=0)
    for (name, shape), p in zip(M.param_specs(CFG), params):
        assert p.shape == shape, name
        if name.endswith(".gamma"):
            assert float(jnp.min(p)) == 1.0
        if name.endswith(".beta"):
            assert float(jnp.max(p)) == 0.0


def test_forward_shapes_and_moments():
    params = M.init_params(CFG, seed=0)
    x = jnp.zeros((2, 16, 16, 3), jnp.float32)
    logits, moments = M.forward(CFG, params, x, train=True)
    assert logits.shape == (2, 10)
    assert len(moments) == len(M.bn_specs(CFG))
    for (name, shape), mom in zip(M.bn_specs(CFG), moments):
        assert mom.shape == shape, name


def test_forward_eval_uses_running_stats():
    params = M.init_params(CFG, seed=0)
    stats = M.init_bn_stats(CFG)
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.standard_normal((4, 16, 16, 3)), jnp.float32)
    logits, moments = M.forward(CFG, params, x, train=False, bn_stats=stats)
    assert logits.shape == (4, 10) and moments == []
    # different stats must change the output
    stats2 = [s + 0.5 for s in stats]
    logits2, _ = M.forward(CFG, params, x, train=False, bn_stats=stats2)
    assert float(jnp.abs(logits - logits2).max()) > 1e-6


def test_batchnorm_train_normalizes():
    rng = np.random.default_rng(1)
    x = jnp.asarray(rng.standard_normal((8, 4, 4, 3)) * 5 + 2, jnp.float32)
    y, (mean, var) = M.batchnorm_train(x, jnp.ones(3), jnp.zeros(3))
    np.testing.assert_allclose(np.asarray(jnp.mean(y, (0, 1, 2))), 0, atol=1e-4)
    np.testing.assert_allclose(np.asarray(jnp.var(y, (0, 1, 2))), 1, atol=1e-2)
    np.testing.assert_allclose(np.asarray(mean), np.asarray(jnp.mean(x, (0, 1, 2))),
                               atol=1e-5)


def test_grad_step_output_arity_and_shapes():
    params = M.init_params(CFG, seed=0)
    rng = np.random.default_rng(2)
    x = jnp.asarray(rng.standard_normal((8, 16, 16, 3)), jnp.float32)
    y = jnp.asarray(rng.integers(0, 10, 8), jnp.int32)
    out = M.grad_step(CFG, params, x, y)
    assert len(out) == len(params) + 3
    for p, g in zip(params, out[:len(params)]):
        assert g.shape == p.shape
    sum_loss, c1, c5 = out[-3:]
    assert np.isfinite(float(sum_loss))
    assert 0 <= int(c1) <= int(c5) <= 8


def test_grad_step_matches_numerical_gradient():
    """Directional finite-difference check through the whole Pallas stack."""
    cfg = M.ModelConfig(width=2, num_classes=4, image_size=8)
    params = M.init_params(cfg, seed=1)
    rng = np.random.default_rng(3)
    x = jnp.asarray(rng.standard_normal((4, 8, 8, 3)), jnp.float32)
    y = jnp.asarray(rng.integers(0, 4, 4), jnp.int32)

    out = M.grad_step(cfg, params, x, y)
    grads = out[:len(params)]
    dirs = [jnp.asarray(rng.standard_normal(p.shape), jnp.float32)
            for p in params]
    analytic = sum(float(jnp.vdot(g, d)) for g, d in zip(grads, dirs))

    eps = 1e-3
    def loss_at(t):
        ps = [p + t * d for p, d in zip(params, dirs)]
        l, _ = M.loss_fn(cfg, ps, x, y)
        return float(l)
    numeric = (loss_at(eps) - loss_at(-eps)) / (2 * eps)
    # relu/maxpool kinks + f32 arithmetic make the centered difference noisy;
    # 20% still catches any sign/scale/indexing bug in the custom VJPs.
    assert abs(analytic - numeric) < 0.2 * max(1.0, abs(analytic)), \
        (analytic, numeric)


def test_train_step_applies_sgd_update():
    params = M.init_params(CFG, seed=0)
    mom = [jnp.zeros_like(p) for p in params]
    rng = np.random.default_rng(4)
    x = jnp.asarray(rng.standard_normal((8, 16, 16, 3)), jnp.float32)
    y = jnp.asarray(rng.integers(0, 10, 8), jnp.int32)
    lr = jnp.asarray([0.05], jnp.float32)

    out = M.train_step(CFG, params, mom, x, y, lr)
    n = len(params)
    new_p, new_m = out[:n], out[n:2 * n]
    grads = M.grad_step(CFG, params, x, y)[:n]
    for p, m, g, p2, m2 in zip(params, mom, grads, new_p, new_m):
        p2r, m2r = ref.sgd_nesterov(p, m, g, 0.05, mu=CFG.momentum,
                                    wd=CFG.weight_decay)
        np.testing.assert_allclose(np.asarray(p2), np.asarray(p2r),
                                   atol=1e-5, rtol=1e-4)
        np.testing.assert_allclose(np.asarray(m2), np.asarray(m2r),
                                   atol=1e-5, rtol=1e-4)


def test_train_step_zero_lr_keeps_params():
    params = M.init_params(CFG, seed=0)
    mom = [jnp.zeros_like(p) for p in params]
    x = jnp.zeros((8, 16, 16, 3), jnp.float32)
    y = jnp.zeros((8,), jnp.int32)
    out = M.train_step(CFG, params, mom, x, y, jnp.asarray([0.0], jnp.float32))
    for p, p2 in zip(params, out[:len(params)]):
        np.testing.assert_allclose(np.asarray(p2), np.asarray(p), atol=0)


def test_bnstats_step_matches_forward_moments():
    params = M.init_params(CFG, seed=0)
    rng = np.random.default_rng(5)
    x = jnp.asarray(rng.standard_normal((8, 16, 16, 3)), jnp.float32)
    moments = M.bnstats_step(CFG, params, x)
    _, expect = M.forward(CFG, params, x, train=True)
    assert len(moments) == len(expect)
    for a, b in zip(moments, expect):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-6)


def test_loss_decreases_under_training():
    """A few fused steps on a fixed batch must reduce the loss — the whole
    L1+L2 stack actually learns."""
    cfg = M.ModelConfig(width=2, num_classes=4, image_size=8)
    params = M.init_params(cfg, seed=2)
    mom = [jnp.zeros_like(p) for p in params]
    rng = np.random.default_rng(6)
    x = jnp.asarray(rng.standard_normal((16, 8, 8, 3)), jnp.float32)
    y = jnp.asarray(rng.integers(0, 4, 16), jnp.int32)
    lr = jnp.asarray([0.1], jnp.float32)

    first = None
    n = len(params)
    for step in range(8):
        out = M.train_step(cfg, params, mom, x, y, lr)
        params, mom = list(out[:n]), list(out[n:2 * n])
        loss = float(out[-3]) / 16
        if first is None:
            first = loss
    assert loss < first, (first, loss)
