"""Generate rust/tests/fixtures/kernel_parity.json.

The JSON pins the numerical behaviour of the python reference kernels
(python/compile/kernels/ref.py) and of the full ResNet9s model entry points
(python/compile/model.py) on small deterministic cases.  The rust native
backend (rust/src/runtime/native/) is asserted against these fixtures in
rust/tests/kernel_parity.rs to 1e-4 — the cross-language twin of the
pytest/hypothesis suite that pins the Pallas kernels to the same oracles.

Run from the repo root (requires jax, CPU is fine):

    python3 python/tools/gen_parity_fixtures.py
"""

import json
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))

import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402
import numpy as np  # noqa: E402

from compile import model as M  # noqa: E402
from compile.kernels import ref  # noqa: E402

OUT = os.path.join(
    os.path.dirname(__file__), "..", "..", "rust", "tests", "fixtures",
    "kernel_parity.json")


def flat(x):
    return [float(v) for v in np.asarray(x, dtype=np.float32).reshape(-1)]


def tensor(x):
    a = np.asarray(x, dtype=np.float32)
    return {"shape": list(a.shape), "data": flat(a)}


def rng(seed):
    return np.random.default_rng(seed)


def matmul_case():
    r = rng(1)
    a = r.standard_normal((3, 4), dtype=np.float32)
    b = r.standard_normal((4, 5), dtype=np.float32)
    bias = r.standard_normal(5, dtype=np.float32)
    return {
        "a": tensor(a),
        "b": tensor(b),
        "bias": flat(bias),
        "out_none": flat(ref.matmul_bias_act(a, b, bias, "none")),
        "out_relu": flat(ref.matmul_bias_act(a, b, bias, "relu")),
        "out_nobias": flat(ref.matmul_bias_act(a, b, None, "none")),
    }


def sgd_case():
    r = rng(2)
    p = jnp.asarray(r.standard_normal(6, dtype=np.float32))
    m = jnp.asarray(r.standard_normal(6, dtype=np.float32))
    grads = [r.standard_normal(6, dtype=np.float32) for _ in range(3)]
    lr, mu, wd = 0.2, 0.9, 0.01
    p0, m0 = p, m
    for g in grads:
        p, m = ref.sgd_nesterov(p, m, jnp.asarray(g), lr, mu=mu, wd=wd)
    return {
        "p0": flat(p0), "m0": flat(m0), "grads": [flat(g) for g in grads],
        "lr": lr, "mu": mu, "wd": wd,
        "p_final": flat(p), "m_final": flat(m),
    }


def xent_case(seed, logits, labels):
    logits = jnp.asarray(logits)
    labels = jnp.asarray(labels, dtype=jnp.int32)
    loss, c1, c5 = ref.cross_entropy(logits, labels)
    dl = ref.cross_entropy_grad(logits, labels, dloss=1.0)
    return {
        "logits": tensor(logits),
        "labels": [int(y) for y in labels],
        "sum_loss": float(loss), "c1": int(c1), "c5": int(c5),
        "dlogits": flat(dl),
    }


def conv_case():
    r = rng(4)
    x = r.standard_normal((2, 4, 5, 3), dtype=np.float32)
    w = r.standard_normal((27, 4), dtype=np.float32)
    patches = M.im2col(jnp.asarray(x))
    y = ref.matmul_bias_act(patches, jnp.asarray(w), None, "none")
    y = np.asarray(y).reshape(2, 4, 5, 4)
    return {"x": tensor(x), "w": tensor(w), "y": tensor(y)}


def batchnorm_case():
    r = rng(5)
    x = r.standard_normal((2, 3, 3, 4), dtype=np.float32)
    gamma = r.standard_normal(4, dtype=np.float32)
    beta = r.standard_normal(4, dtype=np.float32)
    y, (mean, var) = M.batchnorm_train(
        jnp.asarray(x), jnp.asarray(gamma), jnp.asarray(beta))
    return {
        "x": tensor(x), "gamma": flat(gamma), "beta": flat(beta),
        "y": tensor(y), "mean": flat(mean), "var": flat(var),
    }


def maxpool_case():
    r = rng(6)
    x = r.standard_normal((1, 4, 4, 2), dtype=np.float32)
    y = M.maxpool2(jnp.asarray(x))
    return {"x": tensor(x), "y": tensor(y)}


def model_case():
    cfg = M.ModelConfig(width=2, num_classes=4, image_size=8,
                        matmul_backend="xla")
    params = M.init_params(cfg, seed=0)
    r = rng(7)
    batch = 2
    images = np.tanh(
        r.standard_normal((batch, 8, 8, 3), dtype=np.float32))
    labels = np.array([1, 3], dtype=np.int32)
    ij, lj = jnp.asarray(images), jnp.asarray(labels)

    out = M.grad_step(cfg, params, ij, lj)
    grads, (sum_loss, c1, c5) = out[:-3], out[-3:]

    moments = M.bnstats_step(cfg, params, ij)

    # eval with the just-computed moments as running stats (var >= 0)
    bn_stats = list(moments)
    e_loss, e_c1, e_c5 = M.eval_step(cfg, params, bn_stats, ij, lj)

    new = M.train_step(cfg, params, [jnp.zeros_like(p) for p in params],
                       ij, lj, jnp.float32(0.1))
    n = len(params)
    p_after, m_after = new[:n], new[n:2 * n]

    return {
        "width": cfg.width, "num_classes": cfg.num_classes,
        "image_size": cfg.image_size,
        "momentum": cfg.momentum, "weight_decay": cfg.weight_decay,
        "param_names": [name for name, _ in M.param_specs(cfg)],
        "params": [tensor(p) for p in params],
        "bn_names": [name for name, _ in M.bn_specs(cfg)],
        "images": flat(images), "labels": [int(y) for y in labels],
        "batch": batch,
        "grad": {
            "sum_loss": float(sum_loss), "c1": int(c1), "c5": int(c5),
            "grads": [tensor(g) for g in grads],
        },
        "bn_moments": [tensor(m) for m in moments],
        "eval": {"sum_loss": float(e_loss), "c1": int(e_c1),
                 "c5": int(e_c5)},
        "train_step": {
            "lr": 0.1,
            "params_after": [tensor(p) for p in p_after],
            "momentum_after": [tensor(m) for m in m_after],
        },
    }


def main():
    r3 = rng(3)
    logits = r3.standard_normal((4, 7), dtype=np.float32)
    labels = [int(v) for v in r3.integers(0, 7, size=4)]
    # tie case: duplicate the true logit so rank counts strictly-greater only
    tie_logits = np.zeros((2, 6), dtype=np.float32)
    tie_logits[0] = [1.0, 1.0, 0.5, -1.0, 1.0, 0.0]
    tie_logits[1] = [-2.0, 3.0, 3.0, 3.0, 3.0, 3.0]
    fixtures = {
        "matmul": matmul_case(),
        "sgd": sgd_case(),
        "xent": xent_case(3, logits, labels),
        "xent_ties": xent_case(3, tie_logits, [0, 5]),
        "conv3x3": conv_case(),
        "batchnorm": batchnorm_case(),
        "maxpool2": maxpool_case(),
        "model": model_case(),
    }
    os.makedirs(os.path.dirname(OUT), exist_ok=True)
    with open(OUT, "w") as f:
        json.dump(fixtures, f)
    print(f"wrote {os.path.abspath(OUT)} "
          f"({os.path.getsize(OUT) / 1024:.0f} KiB)")


if __name__ == "__main__":
    main()
