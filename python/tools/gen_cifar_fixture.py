#!/usr/bin/env python3
"""Generate the tiny CIFAR-10-binary-format fixture used by
rust/tests/data_source.rs.

The files follow the standard record layout (1 label byte + 3072
channel-planar pixel bytes) with a deterministic pattern, so the Rust
loader test can recompute every expected value independently:

    record i: label = i % 10
              plane byte (c, p) = (i*7 + c*31 + p*13) % 256

The fixture is committed (it is ~25 KB); rerun this script only if the
pattern or the record counts change, and keep the Rust twin of the
pattern (`data::cifar::fixture_record`) in sync.

Usage: python3 python/tools/gen_cifar_fixture.py [out_dir]
       (default out_dir: rust/tests/fixtures/cifar10)
"""

import os
import sys

PLANE = 32 * 32
TRAIN_RECORDS = 6
TEST_RECORDS = 2


def record(i: int) -> bytes:
    b = bytearray([i % 10])
    for c in range(3):
        for p in range(PLANE):
            b.append((i * 7 + c * 31 + p * 13) % 256)
    return bytes(b)


def write(path: str, indices) -> None:
    with open(path, "wb") as f:
        for i in indices:
            f.write(record(i))
    print(f"wrote {path} ({os.path.getsize(path)} bytes)")


def main() -> None:
    out = sys.argv[1] if len(sys.argv) > 1 else "rust/tests/fixtures/cifar10"
    os.makedirs(out, exist_ok=True)
    write(os.path.join(out, "data_batch_1.bin"), range(TRAIN_RECORDS))
    write(os.path.join(out, "test_batch.bin"),
          range(TRAIN_RECORDS, TRAIN_RECORDS + TEST_RECORDS))


if __name__ == "__main__":
    main()
